//! TCP process-cluster engine: the round protocol over real sockets,
//! with topology-aware collective execution.
//!
//! Where [`super::SerialCluster`] drives workers inline and
//! [`super::threaded::ThreadedCluster`] runs them on OS threads,
//! `TcpCluster` runs each worker as a **separate OS process** speaking
//! the [`crate::comm::wire`] frame format over `std::net` sockets — the
//! paper's leader/worker topology with an actual wire in the middle.
//! Two deployment modes:
//!
//! * **external** ([`TcpCluster::connect`]) — the operator launches
//!   `dane worker --listen <addr>` anywhere reachable and lists the
//!   addresses in the config (`"workers": [...]`);
//! * **self-hosted** ([`TcpCluster::self_hosted`]) — the leader spawns
//!   its own worker child processes on loopback (`--listen 127.0.0.1:0`,
//!   parsing the announced port), so `engine: "tcp"` works with zero
//!   setup. The worker binary is the current executable, overridable via
//!   [`set_worker_binary`] (what the test harness uses) or the
//!   `DANE_WORKER_BIN` env var (the CLI-facing knob).
//!
//! ## Collective execution ([`ExecTopology`])
//!
//! The transport executes rounds under one of three strategies:
//!
//! * **`star-seq`** — the leader writes and reads every worker socket
//!   sequentially on its own thread: an O(m·B) critical path through
//!   the leader, kept as the measurable baseline;
//! * **`star`** (default) — one socket-owning I/O thread per worker
//!   connection: the m broadcast-writes and m gather-reads overlap, so
//!   the leader-thread critical path stops scaling with m;
//! * **`tree`** — binomial relay: the leader keeps connections only to
//!   its O(log m) direct children ([`TreePlan`]); a `Peers` frame
//!   tells every worker which child workers to open round connections
//!   to, interior workers relay command frames down and preorder reply
//!   bundles up, and workers whose parent is another worker accept the
//!   parent's connection from their own listener after the leader
//!   closes the setup connection.
//!
//! Whatever the strategy, replies land in rank-indexed slots and the
//! numeric reduction is a rank-order fold at the leader
//! ([`RankGather`]) — so a TCP run stays **trace-bit-identical** to a
//! serial run of the same config across every topology
//! (`tests/topology_parity.rs` pins the whole matrix through
//! `run_experiment`).
//!
//! ## Allocation-free round path
//!
//! The fold-type collectives (gradient+loss, DANE solve, loss, row-norm
//! and their compressed variants) run through
//! [`TcpCluster::fold_round`]: the command is encoded by raw-slice
//! encoders into a pooled `Arc` broadcast slot (a refcount bump per
//! link, no frame vector), replies land in a pooled [`RankGather`]
//! whose rank prefix is folded incrementally as links deliver, and the
//! per-rank fold weights are value-swapped into the fold closure. Under
//! the parallel star and the tree, reply decode happens on the link I/O
//! threads — so the leader thread performs **zero heap allocations per
//! steady-state round**, pinned by `tests/alloc_steady_state.rs`.
//! `star-seq` decodes replies inline on the leader thread and is exempt
//! by design (it exists as the measurable baseline). Per-worker-output
//! collectives (prox, local ERMs) keep the buffered `dispatch` path:
//! they materialize m vectors by contract, so pooling buys nothing.
//!
//! Accounting: the modeled figures (`rounds`, `bytes`,
//! `modeled_seconds`) are counted exactly like the other engines;
//! `CommStats::wire_bytes` additionally reports the bytes *measured on
//! the leader-adjacent sockets* — every round-protocol frame written or
//! read by the leader, instrumentation rounds included; the one-time
//! Init/Peers setup (data distribution) is excluded, mirroring the
//! modeled accounting, and worker-to-worker relay traffic is not
//! observable from the leader (documented in EXPERIMENTS.md
//! §Topologies).
//!
//! Hang safety: every leader-adjacent stream carries read/write
//! timeouts ([`DEFAULT_IO_TIMEOUT`], override via
//! [`TcpCluster::set_io_timeout`]), and the channel wait on a link I/O
//! thread is budgeted by the replies it owes — so a wedged (not just
//! dead) worker surfaces as an `Err` (and at the CLI as an `AlgoError`)
//! instead of deadlocking the leader. A failed round drains every link
//! completely (dead subtrees are answered for with synthesized errors,
//! worker-side by the relays, leader-side by the gather), so surviving
//! sockets never desynchronize. No `.expect`/`.unwrap` anywhere on the
//! socket path.
//!
//! Fault recovery: transport failures surface as
//! [`Error::WorkerLost`] (compute errors stay hard), and
//! [`Cluster::recover`] rebuilds the whole round plane — redial every
//! surviving rank at its retained address, replay the retained
//! Init/InitRef frame (workers are stateless between rounds), respawn
//! self-hosted children whose dial is refused, and re-link the
//! cluster as a **star over the alive ranks**. Fault-free runs never
//! rebuild, so the bit-exact rank-order fold is untouched.

use super::Cluster;
use crate::comm::compress::{self, Codec, CompressedOp, LeaderCompressor};
use crate::comm::topology::{ExecTopology, RankGather, TreePlan, RELAY_CHILD_LOST};
use crate::comm::wire::{
    self, Command as Cmd, InitPayload, InitRefPayload, PeerChild, PeersPayload, Reply,
};
use crate::comm::{Collective, CommStats, NetModel};
use crate::comm::roundchan::{round_channel, RecvTimeoutError, RoundReceiver, RoundSender};
use crate::config::LossKind;
use crate::data::{shard_dataset, shard_indices, Dataset};
use crate::linalg::ops;
use crate::loss::{make_objective, Objective};
use crate::{Error, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Stdio};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Default socket read/write timeout. Rounds are sub-second on every
/// in-tree workload; a worker silent this long is wedged, and an error
/// beats a deadlock.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(60);

/// One round's work order for a link I/O thread: write `frame`, then
/// read `expect` reply frames.
struct LinkJob {
    frame: Arc<Vec<u8>>,
    expect: usize,
}

/// A link I/O thread's round result: one entry per expected reply, in
/// the order they arrived (= the link's preorder rank order), plus the
/// socket bytes moved.
struct LinkBatch {
    replies: Vec<Result<Reply>>,
    bytes: u64,
}

enum LinkIo {
    /// `star-seq`: blocking I/O on the leader thread.
    Inline(TcpStream),
    /// `star`/`tree`: a socket-owning I/O thread fed through the
    /// in-tree rendezvous channel.
    Thread {
        tx: RoundSender<LinkJob>,
        rx: RoundReceiver<LinkBatch>,
        join: Option<JoinHandle<()>>,
    },
    /// Latched after a failure that could leave the link out of
    /// lockstep — a budget timeout (the I/O thread may park a *stale*
    /// batch later; reading it would attribute old replies to a new
    /// round), a mid-frame transport error, or I/O thread death. Every
    /// later round fails fast instead of trusting the link. Replacing
    /// the Thread variant drops its channel ends, so the orphaned I/O
    /// thread exits on its next send/recv (detached; its socket read is
    /// unblocked by the control-handle shutdown in Drop at the latest).
    Dead(String),
}

/// One leader-adjacent connection and the worker ranks served over it —
/// a single rank under the star strategies, a whole subtree in preorder
/// under the tree.
struct Link {
    ranks: Vec<usize>,
    io: LinkIo,
}

/// Kills and reaps self-hosted children if bring-up fails partway.
struct ProcGuard(Vec<Option<Child>>);

impl Drop for ProcGuard {
    fn drop(&mut self) {
        kill_procs(&mut self.0);
    }
}

fn kill_procs(procs: &mut [Option<Child>]) {
    for p in procs.iter_mut() {
        if let Some(mut child) = p.take() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// Leader + m worker processes over TCP.
pub struct TcpCluster {
    topology: ExecTopology,
    links: Vec<Link>,
    /// `try_clone` handles of the leader-adjacent sockets, one per link:
    /// re-arm timeouts, force shutdowns (fault tests, Drop unblock).
    ctrl: Vec<TcpStream>,
    /// Self-hosted child processes by rank (None for external workers
    /// and already-killed children).
    procs: Vec<Option<Child>>,
    obj: Arc<dyn Objective>,
    comm: Collective,
    d: usize,
    /// n_i / N weights for exact gradient averaging (identical to the
    /// in-memory engines — same shards, same reduction order).
    weights: Vec<f64>,
    /// Fold weights actually applied: `weights` verbatim (bitwise
    /// identical) while every rank is alive, renormalized over the
    /// survivors (0.0 at quarantined ranks) after a degrade recovery.
    eff_weights: Vec<f64>,
    /// Ranks quarantined by a degrade recovery; all-false on the
    /// fault-free path and under respawn.
    dead: Vec<bool>,
    n_alive: usize,
    /// Worker addresses by rank, retained for recovery redials
    /// (self-hosted respawns refresh the entry with the new child's
    /// announced address).
    addrs: Vec<String>,
    /// Self-hosted children can be respawned; external workers can
    /// only be redialed.
    hosted: bool,
    /// The encoded Init/InitRef frame per rank, retained so a
    /// recovered worker can be re-initialized without re-sharding.
    /// By-value frames hold the shard rows (one extra copy of the
    /// training data on the leader); by-ref frames are O(1) each.
    init_frames: Vec<Vec<u8>>,
    /// Whether the links follow the tree plan. Bring-up sets this from
    /// the topology; recovery rebuilds always produce star links, so
    /// command routing consults this, not `topology`.
    tree_links: bool,
    row_sq: Option<f64>,
    /// Bytes measured on the leader-adjacent sockets (round frames
    /// only; Init/Peers setup excluded).
    wire_bytes: u64,
    /// Bytes measured during bring-up (Init or InitRef frames, Peers
    /// frames, and their acks): the one-time data-distribution cost.
    /// By-value Init ships every shard row, O(n·d); by-ref InitRef
    /// ships one small frame per worker, O(m). Reported separately
    /// from `wire_bytes` and *not* cleared by `reset_comm`.
    startup_bytes: u64,
    /// Reusable encode buffer — one frame encoded per broadcast
    /// (buffered collectives and the point-to-point path).
    enc: Vec<u8>,
    /// Pooled broadcast frame for the fold-type collectives
    /// ([`TcpCluster::fold_round`]): re-encoded in place each round
    /// ([`bcast_slot`]) and shipped to every link as an `Arc` refcount
    /// bump. Link I/O threads drop their clones once the round's write
    /// completes, so by the next encode the slot is unique again and
    /// the buffer is reused — no per-round frame allocation.
    bcast: Arc<Vec<u8>>,
    /// Pooled rank gather for the fold-type collectives; re-armed
    /// (capacity retained) at the top of every `fold_round`.
    gather: RankGather,
    /// Reusable receive buffer (inline reads + setup acks).
    frame: Vec<u8>,
    io_timeout: Duration,
    /// Leader-side codec + error-feedback state for compressed round
    /// payloads ([`TcpCluster::set_compression`]); `None` runs the
    /// uncompressed protocol, frame-for-frame identical to before.
    compressor: Option<LeaderCompressor>,
    /// Decode scratch for compressed replies.
    dec: Vec<f64>,
    /// Signed surplus of raw-equivalent payload over measured socket
    /// bytes, accumulated per compressed frame (a top-k frame with k
    /// close to d can *exceed* its raw equivalent, hence signed).
    /// `comm_stats` reports `payload_bytes_raw = wire_bytes + this`, so
    /// it is exactly `wire_bytes` when no codec is active.
    payload_raw_extra: i64,
}

impl TcpCluster {
    /// Connect to externally-launched `dane worker --listen` processes.
    /// `m = addrs.len()`; shards are assigned to addresses in order.
    /// Under `ExecTopology::Tree` the workers must be able to reach
    /// *each other* at the listed addresses (they open the relay
    /// connections the `Peers` frames name).
    #[allow(clippy::too_many_arguments)]
    pub fn connect(
        ds: &Dataset,
        loss: LossKind,
        lambda: f64,
        addrs: &[String],
        seed: u64,
        net: NetModel,
        gram_threads: Option<usize>,
        timeout: Option<Duration>,
        topology: ExecTopology,
    ) -> Result<Self> {
        Self::connect_impl(
            ds, loss, lambda, addrs, seed, net, gram_threads, timeout, topology, None,
        )
    }

    /// Like [`TcpCluster::connect`], but ship shards **by reference**:
    /// each worker gets one small [`wire::InitRefPayload`] frame naming
    /// the libsvm file at `path` plus the sharding parameters
    /// `(n, m, seed)`, and streams its own rows from local disk —
    /// O(m) startup bytes instead of O(n·d). Requirements: the file
    /// must hold exactly `ds.n()` data rows in dataset order (true for
    /// any dataset loaded from that same file — libsvm loads carry no
    /// test split) and be readable at `path` on every worker host.
    /// Shard assignment is bit-identical to by-value `connect`.
    #[allow(clippy::too_many_arguments)]
    pub fn connect_by_ref(
        ds: &Dataset,
        loss: LossKind,
        lambda: f64,
        addrs: &[String],
        seed: u64,
        net: NetModel,
        gram_threads: Option<usize>,
        timeout: Option<Duration>,
        topology: ExecTopology,
        path: &str,
    ) -> Result<Self> {
        Self::connect_impl(
            ds,
            loss,
            lambda,
            addrs,
            seed,
            net,
            gram_threads,
            timeout,
            topology,
            Some(path.to_string()),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn connect_impl(
        ds: &Dataset,
        loss: LossKind,
        lambda: f64,
        addrs: &[String],
        seed: u64,
        net: NetModel,
        gram_threads: Option<usize>,
        timeout: Option<Duration>,
        topology: ExecTopology,
        data_path: Option<String>,
    ) -> Result<Self> {
        if addrs.is_empty() {
            return Err(Error::Config("tcp engine needs >= 1 worker address".into()));
        }
        let io_timeout = timeout.unwrap_or(DEFAULT_IO_TIMEOUT);
        let mut streams = Vec::with_capacity(addrs.len());
        for (i, addr) in addrs.iter().enumerate() {
            let stream = TcpStream::connect(addr).map_err(|e| {
                Error::Runtime(format!("tcp: connect worker {i} at {addr}: {e}"))
            })?;
            streams.push(stream);
        }
        let procs = (0..addrs.len()).map(|_| None).collect();
        Self::bring_up(
            ds,
            loss,
            lambda,
            seed,
            net,
            gram_threads,
            io_timeout,
            topology,
            streams,
            addrs.to_vec(),
            procs,
            data_path,
        )
    }

    /// Spawn `m` worker child processes on loopback and connect to them.
    /// The worker binary is the [`set_worker_binary`] override if set,
    /// else `$DANE_WORKER_BIN`, else the current executable (which is
    /// the `dane` bin when launched from the CLI).
    #[allow(clippy::too_many_arguments)]
    pub fn self_hosted(
        ds: &Dataset,
        loss: LossKind,
        lambda: f64,
        m: usize,
        seed: u64,
        net: NetModel,
        gram_threads: Option<usize>,
        timeout: Option<Duration>,
        topology: ExecTopology,
    ) -> Result<Self> {
        Self::self_hosted_impl(
            ds, loss, lambda, m, seed, net, gram_threads, timeout, topology, None,
        )
    }

    /// Like [`TcpCluster::self_hosted`], but with by-reference data
    /// distribution (see [`TcpCluster::connect_by_ref`]). Self-hosted
    /// children run on the same host, so "readable on every worker
    /// host" is just "readable here".
    #[allow(clippy::too_many_arguments)]
    pub fn self_hosted_by_ref(
        ds: &Dataset,
        loss: LossKind,
        lambda: f64,
        m: usize,
        seed: u64,
        net: NetModel,
        gram_threads: Option<usize>,
        timeout: Option<Duration>,
        topology: ExecTopology,
        path: &str,
    ) -> Result<Self> {
        Self::self_hosted_impl(
            ds,
            loss,
            lambda,
            m,
            seed,
            net,
            gram_threads,
            timeout,
            topology,
            Some(path.to_string()),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn self_hosted_impl(
        ds: &Dataset,
        loss: LossKind,
        lambda: f64,
        m: usize,
        seed: u64,
        net: NetModel,
        gram_threads: Option<usize>,
        timeout: Option<Duration>,
        topology: ExecTopology,
        data_path: Option<String>,
    ) -> Result<Self> {
        if m == 0 {
            return Err(Error::Config("tcp engine needs >= 1 worker".into()));
        }
        let bin = worker_binary()?;
        let io_timeout = timeout.unwrap_or(DEFAULT_IO_TIMEOUT);
        let mut procs: Vec<Option<Child>> = Vec::with_capacity(m);
        let mut streams = Vec::with_capacity(m);
        let mut addrs = Vec::with_capacity(m);
        for i in 0..m {
            match spawn_worker_process(&bin, i, io_timeout) {
                Ok((child, addr)) => {
                    procs.push(Some(child));
                    match TcpStream::connect(&addr) {
                        Ok(s) => {
                            streams.push(s);
                            addrs.push(addr);
                        }
                        Err(e) => {
                            kill_procs(&mut procs);
                            return Err(Error::Runtime(format!(
                                "tcp: connect spawned worker {i} at {addr}: {e}"
                            )));
                        }
                    }
                }
                Err(e) => {
                    kill_procs(&mut procs);
                    return Err(e);
                }
            }
        }
        Self::bring_up(
            ds, loss, lambda, seed, net, gram_threads, io_timeout, topology, streams,
            addrs, procs, data_path,
        )
    }

    /// Shared bring-up: configure the setup streams, shard the dataset
    /// (same seed discipline as the in-memory engines), ship Init (and,
    /// for the tree, Peers) frames in lockstep, then partition the
    /// connections into round-plane links per the topology. On any
    /// failure the `ProcGuard` reaps already-spawned children.
    #[allow(clippy::too_many_arguments)]
    fn bring_up(
        ds: &Dataset,
        loss: LossKind,
        lambda: f64,
        seed: u64,
        net: NetModel,
        gram_threads: Option<usize>,
        io_timeout: Duration,
        topology: ExecTopology,
        streams: Vec<TcpStream>,
        addrs: Vec<String>,
        procs: Vec<Option<Child>>,
        data_path: Option<String>,
    ) -> Result<Self> {
        let m = streams.len();
        let mut guard = ProcGuard(procs);
        for (i, s) in streams.iter().enumerate() {
            configure_stream(s, i, io_timeout)?;
        }

        let mut streams = streams;
        let mut enc = Vec::new();
        let mut frame = Vec::new();
        let mut startup_bytes: u64 = 0;
        let mut init_frames: Vec<Vec<u8>> = Vec::with_capacity(m);
        // Init handshake: the leader is the single source of sharding
        // truth; worker processes need no config file. Excluded from
        // the per-round accounting (modeled and wire) but measured as
        // `startup_bytes`: by value every shard row crosses the wire
        // (O(n·d)), by reference one InitRef frame per worker does
        // (O(m)) and workers stream their rows from local disk.
        let weights: Vec<f64> = match &data_path {
            None => {
                let shards = shard_dataset(ds, m, seed);
                if shards.len() != m {
                    return Err(Error::Config(format!(
                        "tcp: {} shards for {m} workers",
                        shards.len()
                    )));
                }
                let total: usize = shards.iter().map(|s| s.n_effective()).sum();
                let weights = shards
                    .iter()
                    .map(|s| s.n_effective() as f64 / total as f64)
                    .collect();
                for (i, shard) in shards.into_iter().enumerate() {
                    let init = Cmd::Init(Box::new(InitPayload {
                        worker_id: i,
                        loss_name: loss.name().to_string(),
                        lambda,
                        gram_threads,
                        shard,
                    }));
                    wire::encode_command(&init, &mut enc)?;
                    startup_bytes += enc.len() as u64;
                    init_frames.push(enc.clone());
                    streams[i]
                        .write_all(&enc)
                        .map_err(|e| io_err(i, "init send", &e))?;
                }
                weights
            }
            Some(path) => {
                if ds.n() < m {
                    return Err(Error::Config(format!(
                        "tcp: by-ref init needs >= 1 row per worker ({} rows, {m} workers)",
                        ds.n()
                    )));
                }
                // Same `(n, m, seed)` triple the by-value path feeds
                // `shard_dataset`, so assignment is bit-identical.
                let rows = shard_indices(ds.n(), m, seed);
                let total = ds.n() as f64;
                let weights = rows.iter().map(|r| r.len() as f64 / total).collect();
                for i in 0..m {
                    let init = Cmd::InitRef(Box::new(InitRefPayload {
                        worker_id: i,
                        loss_name: loss.name().to_string(),
                        lambda,
                        gram_threads,
                        path: path.clone(),
                        dim: ds.d(),
                        n: ds.n(),
                        machines: m,
                        shard_seed: seed,
                    }));
                    wire::encode_command(&init, &mut enc)?;
                    startup_bytes += enc.len() as u64;
                    init_frames.push(enc.clone());
                    streams[i]
                        .write_all(&enc)
                        .map_err(|e| io_err(i, "init send", &e))?;
                }
                weights
            }
        };
        for (i, s) in streams.iter_mut().enumerate() {
            startup_bytes += read_setup_ack(s, &mut frame, i, "init")?;
        }

        // Tree setup: every worker learns its children (rank, address,
        // subtree preorder) and whether its round-plane parent is
        // another worker. Parents dial children while handling their
        // own Peers; the accept backlog makes the ordering race-free.
        let plan = topology.is_tree().then(|| TreePlan::new(m));
        if let Some(plan) = &plan {
            for i in 0..m {
                let children: Vec<PeerChild> = plan
                    .children_of(i)
                    .iter()
                    .map(|&c| PeerChild {
                        rank: c,
                        addr: addrs[c].clone(),
                        ranks: plan.subtree_ranks(c),
                    })
                    .collect();
                let peers = Cmd::Peers(Box::new(PeersPayload {
                    children,
                    expect_parent: !plan.is_root_child(i),
                }));
                wire::encode_command(&peers, &mut enc)?;
                startup_bytes += enc.len() as u64;
                streams[i]
                    .write_all(&enc)
                    .map_err(|e| io_err(i, "peers send", &e))?;
            }
            for (i, s) in streams.iter_mut().enumerate() {
                startup_bytes += read_setup_ack(s, &mut frame, i, "peers")?;
            }
        }

        // Partition into round-plane links. Non-root setup connections
        // are dropped: the EOF tells interior workers to accept their
        // parent's (already-dialed) connection.
        let rank_sets: Vec<Vec<usize>> = match &plan {
            Some(plan) => plan.root_links().to_vec(),
            None => (0..m).map(|i| vec![i]).collect(),
        };
        let mut streams: Vec<Option<TcpStream>> = streams.into_iter().map(Some).collect();
        let mut links = Vec::with_capacity(rank_sets.len());
        let mut ctrl = Vec::with_capacity(rank_sets.len());
        for ranks in rank_sets {
            let stream = streams[ranks[0]].take().ok_or_else(|| {
                Error::Runtime(format!("tcp: root stream {} claimed twice", ranks[0]))
            })?;
            ctrl.push(stream.try_clone().map_err(|e| {
                Error::Runtime(format!("tcp: clone control handle: {e}"))
            })?);
            let io = match topology {
                ExecTopology::StarSeq => LinkIo::Inline(stream),
                ExecTopology::Star | ExecTopology::Tree => {
                    spawn_link_io(stream, ranks[0])
                }
            };
            links.push(Link { ranks, io });
        }
        drop(streams);

        let procs = std::mem::take(&mut guard.0);
        let hosted = procs.iter().any(|p| p.is_some());
        let n_alive = weights.len();
        Ok(TcpCluster {
            topology,
            links,
            ctrl,
            procs,
            obj: make_objective(loss, lambda),
            comm: Collective::new(net),
            d: ds.d(),
            eff_weights: weights.clone(),
            weights,
            dead: vec![false; n_alive],
            n_alive,
            addrs,
            hosted,
            init_frames,
            tree_links: topology.is_tree(),
            row_sq: None,
            wire_bytes: 0,
            startup_bytes,
            enc,
            bcast: Arc::new(Vec::new()),
            gather: RankGather::new(n_alive),
            frame,
            io_timeout,
            compressor: None,
            dec: Vec::new(),
            payload_raw_extra: 0,
        })
    }

    /// Compress the O(d) round payloads (GradLoss / DaneSolve commands
    /// and their replies) with `codec`, optionally with error feedback.
    /// Eval instrumentation gathers and the Theorem-5 first round stay
    /// uncompressed — only the counted optimization rounds shrink.
    /// Relay workers forward compressed frames verbatim (`dispatch`
    /// ships opaque byte frames), so the tree topology never re-expands
    /// a payload in flight.
    pub fn set_compression(&mut self, codec: Codec, error_feedback: bool, seed: u64) {
        self.compressor = Some(LeaderCompressor::new(codec, error_feedback, seed));
    }

    /// Re-arm the socket timeouts (tests tighten them to exercise the
    /// wedged-worker path quickly). The control clones share the
    /// underlying sockets with the link I/O threads, so the new options
    /// apply immediately.
    pub fn set_io_timeout(&mut self, timeout: Duration) -> Result<()> {
        self.io_timeout = timeout;
        for (li, c) in self.ctrl.iter().enumerate() {
            c.set_read_timeout(Some(timeout))
                .map_err(|e| Error::Runtime(format!("tcp: link {li} read timeout: {e}")))?;
            c.set_write_timeout(Some(timeout))
                .map_err(|e| Error::Runtime(format!("tcp: link {li} write timeout: {e}")))?;
        }
        Ok(())
    }

    /// Kill worker `rank` (self-hosted mode: SIGKILL the child process;
    /// any mode: shut down its leader-adjacent socket if it heads a
    /// link) — the fault-injection tests' "machine dies mid-run". The
    /// very next round observes the death deterministically; for an
    /// interior tree worker the kill propagates through its parent's
    /// relay (synthesized error replies), exercising the genuine
    /// relay-failure path.
    pub fn kill_worker(&mut self, rank: usize) {
        if let Some(slot) = self.procs.get_mut(rank) {
            if let Some(mut child) = slot.take() {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
        if let Some(li) = self.links.iter().position(|l| l.ranks.first() == Some(&rank))
        {
            let _ = self.ctrl[li].shutdown(std::net::Shutdown::Both);
        }
    }

    /// Shut down and drain every leader-adjacent link (joining the I/O
    /// threads), keeping worker processes, addresses, and retained
    /// init frames. Workers see EOF at a frame boundary and loop back
    /// to accepting, ready for a recovery redial.
    fn teardown_links(&mut self) {
        for c in &self.ctrl {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
        for link in self.links.drain(..) {
            match link.io {
                LinkIo::Inline(stream) => drop(stream),
                LinkIo::Thread { tx, rx, join } => {
                    drop(tx);
                    drop(rx);
                    if let Some(j) = join {
                        let _ = j.join();
                    }
                }
                // latched-dead links already dropped their channel
                // ends; the orphaned I/O thread exits on its own
                LinkIo::Dead(_) => {}
            }
        }
        self.ctrl.clear();
    }

    /// Dial rank's retained address, replay its retained Init frame,
    /// and consume the ack — a fresh worker session ready for rounds.
    fn redial_rank(&mut self, rank: usize) -> Result<TcpStream> {
        let addr = self.addrs[rank].clone();
        let mut stream = TcpStream::connect(&addr).map_err(|e| {
            Error::WorkerLost(format!("tcp: redial worker {rank} at {addr}: {e}"))
        })?;
        configure_stream(&stream, rank, self.io_timeout)?;
        stream.write_all(&self.init_frames[rank]).map_err(|e| {
            Error::WorkerLost(format!("tcp: worker {rank} re-init: {e}"))
        })?;
        self.startup_bytes += self.init_frames[rank].len() as u64;
        self.startup_bytes +=
            read_setup_ack(&mut stream, &mut self.frame, rank, "re-init")?;
        Ok(stream)
    }

    /// Kill and reap the dead self-hosted child at `rank`, spawn a
    /// fresh worker process, record its announced address, and
    /// initialize it.
    fn respawn_rank(&mut self, rank: usize, dial_err: Error) -> Result<TcpStream> {
        let bin = worker_binary()?;
        if let Some(slot) = self.procs.get_mut(rank) {
            if let Some(mut child) = slot.take() {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
        let (child, addr) = spawn_worker_process(&bin, rank, self.io_timeout)
            .map_err(|e| Error::WorkerLost(format!("{dial_err}; respawn: {e}")))?;
        self.procs[rank] = Some(child);
        self.addrs[rank] = addr;
        self.redial_rank(rank)
    }

    /// Full-rebuild recovery: abandon every leader-adjacent
    /// connection, redial each previously-alive rank (respawning
    /// self-hosted children whose dial fails when `respawn` is set,
    /// quarantining unreachable ranks otherwise), and rebuild the
    /// round plane as a star over the alive ranks — a recovered run
    /// never relays through a possibly-dead interior worker. Under
    /// `respawn` any unrecoverable rank is an error (the supervisor
    /// backs off and calls again); under degrade the survivor count is
    /// returned and the fold weights are renormalized.
    fn recover_impl(&mut self, respawn: bool) -> Result<usize> {
        let m = self.weights.len();
        self.teardown_links();
        let mut streams: Vec<Option<TcpStream>> = (0..m).map(|_| None).collect();
        for rank in 0..m {
            if self.dead[rank] {
                continue;
            }
            match self.redial_rank(rank) {
                Ok(s) => streams[rank] = Some(s),
                Err(first) if respawn && self.hosted => {
                    streams[rank] = Some(self.respawn_rank(rank, first)?);
                }
                // External worker under respawn: nothing to spawn —
                // the supervisor backs off and redials.
                Err(first) if respawn => return Err(first),
                Err(_) => {
                    if let Some(slot) = self.procs.get_mut(rank) {
                        if let Some(mut child) = slot.take() {
                            let _ = child.kill();
                            let _ = child.wait();
                        }
                    }
                    self.dead[rank] = true;
                }
            }
        }
        for (rank, slot) in streams.iter_mut().enumerate() {
            let Some(stream) = slot.take() else { continue };
            self.ctrl.push(stream.try_clone().map_err(|e| {
                Error::Runtime(format!("tcp: clone control handle: {e}"))
            })?);
            let io = match self.topology {
                ExecTopology::StarSeq => LinkIo::Inline(stream),
                ExecTopology::Star | ExecTopology::Tree => spawn_link_io(stream, rank),
            };
            self.links.push(Link { ranks: vec![rank], io });
        }
        self.tree_links = false;
        self.n_alive = self.dead.iter().filter(|&&dd| !dd).count();
        if self.dead.iter().any(|&dd| dd) {
            let wsum: f64 =
                (0..m).filter(|&r| !self.dead[r]).map(|r| self.weights[r]).sum();
            self.eff_weights = (0..m)
                .map(|r| {
                    if self.dead[r] {
                        0.0
                    } else {
                        self.weights[r] / wsum
                    }
                })
                .collect();
            self.row_sq = None;
        } else {
            self.eff_weights = self.weights.clone();
        }
        Ok(self.n_alive)
    }

    fn unexpected(&self, i: usize) -> Error {
        Error::Runtime(format!("worker {i}: unexpected reply type"))
    }

    /// One collective round: send `frames[li]` over link `li`, gather
    /// every link's full reply bundle, slot replies by rank, surface
    /// the lowest-rank error after draining everything. All writes go
    /// out before any read (threaded links overlap both on their own).
    /// Quarantined ranks have no link and come back as `None` slots.
    fn dispatch(&mut self, frames: Vec<Arc<Vec<u8>>>) -> Result<Vec<Option<Reply>>> {
        debug_assert_eq!(frames.len(), self.links.len());
        let m = self.weights.len();
        let io_timeout = self.io_timeout;
        let budget = |expect: usize| {
            io_timeout.checked_mul(expect as u32 + 2).unwrap_or(io_timeout)
        };
        let TcpCluster { links, frame: buf, wire_bytes, dead, .. } = self;
        let mut gather = RankGather::new(m);
        let mut bytes = 0u64;
        let mut pending = vec![false; links.len()];
        for (li, frame) in frames.iter().enumerate() {
            let link = &mut links[li];
            let expect = link.ranks.len();
            let mut latch: Option<String> = None;
            match &mut link.io {
                LinkIo::Thread { tx, .. } => {
                    match tx.send(LinkJob { frame: frame.clone(), expect }) {
                        Ok(()) => pending[li] = true,
                        Err(_) => {
                            let msg = "link I/O thread died".to_string();
                            fail_ranks(&mut gather, &link.ranks, &msg);
                            latch = Some(msg);
                        }
                    }
                }
                LinkIo::Inline(stream) => match stream.write_all(frame.as_slice()) {
                    Ok(()) => {
                        bytes += frame.len() as u64;
                        pending[li] = true;
                    }
                    Err(e) => {
                        let msg = describe_io("send", &e);
                        fail_ranks(&mut gather, &link.ranks, &msg);
                        latch = Some(msg);
                    }
                },
                LinkIo::Dead(msg) => {
                    let msg = msg.clone();
                    fail_ranks(&mut gather, &link.ranks, &msg);
                }
            }
            if let Some(msg) = latch {
                link.io = LinkIo::Dead(msg);
            }
        }
        drop(frames);
        for (li, link) in links.iter_mut().enumerate() {
            if !pending[li] {
                continue;
            }
            let mut latch: Option<String> = None;
            match &mut link.io {
                LinkIo::Thread { rx, .. } => {
                    match rx.recv_timeout(budget(link.ranks.len())) {
                        Ok(batch) => {
                            bytes += batch.bytes;
                            for (rank, r) in link.ranks.iter().zip(batch.replies) {
                                // keep the transport/compute split the
                                // I/O thread already made
                                gather.put(
                                    *rank,
                                    r.map_err(|e| match e {
                                        Error::WorkerLost(msg) => {
                                            Error::WorkerLost(format!(
                                                "tcp: worker {rank}: {msg}"
                                            ))
                                        }
                                        e => Error::Runtime(format!(
                                            "tcp: worker {rank}: {e}"
                                        )),
                                    }),
                                );
                            }
                        }
                        Err(RecvTimeoutError::Timeout) => {
                            // The I/O thread may deliver this round's
                            // batch *later*; trusting the link again
                            // would attribute stale replies to a future
                            // round — latch it dead instead.
                            let msg =
                                "wedged: no reply within the link budget".to_string();
                            fail_ranks(&mut gather, &link.ranks, &msg);
                            latch = Some(msg);
                        }
                        Err(RecvTimeoutError::Disconnected) => {
                            let msg = "link I/O thread died".to_string();
                            fail_ranks(&mut gather, &link.ranks, &msg);
                            latch = Some(msg);
                        }
                    }
                }
                LinkIo::Inline(stream) => {
                    let mut failed: Option<String> = None;
                    for k in 0..link.ranks.len() {
                        let rank = link.ranks[k];
                        if let Some(msg) = &failed {
                            gather.put(
                                rank,
                                Err(Error::WorkerLost(format!(
                                    "tcp: worker {rank}: {msg}"
                                ))),
                            );
                            continue;
                        }
                        match wire::read_frame(stream, buf) {
                            Ok(Some(n)) => {
                                bytes += n as u64;
                                gather.put(
                                    rank,
                                    wire::decode_reply(buf).map_err(|e| {
                                        Error::Runtime(format!(
                                            "tcp: worker {rank} sent a malformed reply: {e}"
                                        ))
                                    }),
                                );
                            }
                            Ok(None) => {
                                let msg = "connection closed mid-round".to_string();
                                gather.put(
                                    rank,
                                    Err(Error::WorkerLost(format!(
                                        "tcp: worker {rank}: {msg}"
                                    ))),
                                );
                                failed = Some(msg);
                            }
                            Err(Error::Io(e)) => {
                                let msg = describe_io("reply read", &e);
                                gather.put(
                                    rank,
                                    Err(Error::WorkerLost(format!(
                                        "tcp: worker {rank}: {msg}"
                                    ))),
                                );
                                failed = Some(msg);
                            }
                            Err(e) => {
                                let msg = e.to_string();
                                gather.put(
                                    rank,
                                    Err(Error::Runtime(format!(
                                        "tcp: worker {rank}: {msg}"
                                    ))),
                                );
                                failed = Some(msg);
                            }
                        }
                    }
                    // A mid-bundle transport failure leaves unread (or
                    // unsent) frames in flight: the stream is out of
                    // lockstep, never trustworthy again.
                    latch = failed;
                }
                LinkIo::Dead(_) => {}
            }
            if let Some(msg) = latch {
                link.io = LinkIo::Dead(msg);
            }
        }
        *wire_bytes += bytes;
        gather.into_result_masked(dead)
    }

    /// Broadcast the frame sitting in the pooled [`bcast_slot`] and fold
    /// the replies **incrementally in rank order**: each link's batch is
    /// slotted as it arrives and [`RankGather::drain_fold`] consumes the
    /// ready rank prefix immediately, so the leader's fold work overlaps
    /// the remaining links' network waits. The fold consumes the slots
    /// in exactly the buffered path's rank order, so every bit of the
    /// result is identical (`tests/topology_parity.rs` pins incremental
    /// against buffered across the matrix).
    ///
    /// Steady state allocates nothing on the leader thread: the
    /// broadcast is an `Arc` refcount bump per link, the gather's slots
    /// are pooled, and link I/O threads decode replies on their own
    /// threads (`tests/alloc_steady_state.rs` pins this under the
    /// parallel star; `star-seq` decodes inline on the leader and is
    /// exempt by design).
    ///
    /// Send/receive discipline matches `dispatch`: all writes go out
    /// before any read, every link drains completely, transport
    /// failures latch the link dead, and the lowest-rank error wins. A
    /// link that is not `Dead` when the receive phase starts accepted
    /// its job — the same property `dispatch` tracks with its `pending`
    /// mask, minus the per-round allocation.
    fn fold_round(
        &mut self,
        fold: &mut dyn FnMut(usize, Reply) -> Result<()>,
    ) -> Result<()> {
        let m = self.weights.len();
        let io_timeout = self.io_timeout;
        let budget = |expect: usize| {
            io_timeout.checked_mul(expect as u32 + 2).unwrap_or(io_timeout)
        };
        let TcpCluster { links, frame: buf, wire_bytes, dead, gather, bcast, .. } =
            self;
        gather.reset(m);
        let mut bytes = 0u64;
        for link in links.iter_mut() {
            let expect = link.ranks.len();
            let mut latch: Option<String> = None;
            match &mut link.io {
                LinkIo::Thread { tx, .. } => {
                    if tx.send(LinkJob { frame: bcast.clone(), expect }).is_err() {
                        let msg = "link I/O thread died".to_string();
                        fail_ranks(gather, &link.ranks, &msg);
                        latch = Some(msg);
                    }
                }
                LinkIo::Inline(stream) => match stream.write_all(bcast.as_slice()) {
                    Ok(()) => bytes += bcast.len() as u64,
                    Err(e) => {
                        let msg = describe_io("send", &e);
                        fail_ranks(gather, &link.ranks, &msg);
                        latch = Some(msg);
                    }
                },
                LinkIo::Dead(msg) => {
                    let msg = msg.clone();
                    fail_ranks(gather, &link.ranks, &msg);
                }
            }
            if let Some(msg) = latch {
                link.io = LinkIo::Dead(msg);
            }
        }
        for link in links.iter_mut() {
            let mut latch: Option<String> = None;
            match &mut link.io {
                LinkIo::Thread { rx, .. } => {
                    match rx.recv_timeout(budget(link.ranks.len())) {
                        Ok(batch) => {
                            bytes += batch.bytes;
                            for (rank, r) in link.ranks.iter().zip(batch.replies) {
                                // keep the transport/compute split the
                                // I/O thread already made
                                gather.put(
                                    *rank,
                                    r.map_err(|e| match e {
                                        Error::WorkerLost(msg) => {
                                            Error::WorkerLost(format!(
                                                "tcp: worker {rank}: {msg}"
                                            ))
                                        }
                                        e => Error::Runtime(format!(
                                            "tcp: worker {rank}: {e}"
                                        )),
                                    }),
                                );
                            }
                        }
                        Err(RecvTimeoutError::Timeout) => {
                            // Same latch rationale as `dispatch`: a late
                            // batch from this thread must never be
                            // attributed to a future round.
                            let msg =
                                "wedged: no reply within the link budget".to_string();
                            fail_ranks(gather, &link.ranks, &msg);
                            latch = Some(msg);
                        }
                        Err(RecvTimeoutError::Disconnected) => {
                            let msg = "link I/O thread died".to_string();
                            fail_ranks(gather, &link.ranks, &msg);
                            latch = Some(msg);
                        }
                    }
                }
                LinkIo::Inline(stream) => {
                    let mut failed: Option<String> = None;
                    for k in 0..link.ranks.len() {
                        let rank = link.ranks[k];
                        if let Some(msg) = &failed {
                            gather.put(
                                rank,
                                Err(Error::WorkerLost(format!(
                                    "tcp: worker {rank}: {msg}"
                                ))),
                            );
                            continue;
                        }
                        match wire::read_frame(stream, buf) {
                            Ok(Some(n)) => {
                                bytes += n as u64;
                                gather.put(
                                    rank,
                                    wire::decode_reply(buf).map_err(|e| {
                                        Error::Runtime(format!(
                                            "tcp: worker {rank} sent a malformed reply: {e}"
                                        ))
                                    }),
                                );
                            }
                            Ok(None) => {
                                let msg = "connection closed mid-round".to_string();
                                gather.put(
                                    rank,
                                    Err(Error::WorkerLost(format!(
                                        "tcp: worker {rank}: {msg}"
                                    ))),
                                );
                                failed = Some(msg);
                            }
                            Err(Error::Io(e)) => {
                                let msg = describe_io("reply read", &e);
                                gather.put(
                                    rank,
                                    Err(Error::WorkerLost(format!(
                                        "tcp: worker {rank}: {msg}"
                                    ))),
                                );
                                failed = Some(msg);
                            }
                            Err(e) => {
                                let msg = e.to_string();
                                gather.put(
                                    rank,
                                    Err(Error::Runtime(format!(
                                        "tcp: worker {rank}: {msg}"
                                    ))),
                                );
                                failed = Some(msg);
                            }
                        }
                    }
                    latch = failed;
                }
                LinkIo::Dead(_) => {}
            }
            if let Some(msg) = latch {
                link.io = LinkIo::Dead(msg);
            }
            // Fold whatever rank prefix this batch completed while the
            // remaining links are still in flight.
            gather.drain_fold(dead, fold);
        }
        *wire_bytes += bytes;
        gather.finish_fold(dead, fold)
    }

    /// Broadcast the frame sitting in `self.enc` to every link and
    /// gather the full cluster's replies; recovers the encode buffer
    /// when every link has released its share.
    fn broadcast_round(&mut self) -> Result<Vec<Option<Reply>>> {
        let frame = Arc::new(std::mem::take(&mut self.enc));
        let frames = vec![frame.clone(); self.links.len()];
        let out = self.dispatch(frames);
        if let Ok(buf) = Arc::try_unwrap(frame) {
            self.enc = buf;
        }
        out
    }

    /// Point-to-point round: send the frame in `self.enc` over the one
    /// link that holds `rank` and read a single reply (the tree relays
    /// route a `For` envelope; the star strategies address the worker's
    /// own link).
    fn fetch_single(&mut self, rank: usize) -> Result<Reply> {
        let io_timeout = self.io_timeout;
        let budget = io_timeout.checked_mul(3).unwrap_or(io_timeout);
        let TcpCluster { links, enc, frame: buf, wire_bytes, .. } = self;
        let li = links
            .iter()
            .position(|l| l.ranks.contains(&rank))
            .ok_or_else(|| Error::Runtime(format!("tcp: no link holds worker {rank}")))?;
        // Transport failures that could leave the link out of lockstep
        // latch it dead (same discipline as `dispatch`); the error
        // still surfaces to the caller.
        let mut latch: Option<String> = None;
        let result = match &mut links[li].io {
            LinkIo::Thread { tx, rx, .. } => loop {
                // single-iteration loop: a `break` target so every
                // failure path falls through to the latch below
                let frame = Arc::new(std::mem::take(enc));
                if tx.send(LinkJob { frame: frame.clone(), expect: 1 }).is_err() {
                    let msg = "link I/O thread died".to_string();
                    latch = Some(msg.clone());
                    break Err(Error::WorkerLost(format!("tcp: worker {rank}: {msg}")));
                }
                let batch = match rx.recv_timeout(budget) {
                    Ok(b) => b,
                    Err(RecvTimeoutError::Timeout) => {
                        let msg = format!("wedged: no reply within {budget:?}");
                        latch = Some(msg.clone());
                        break Err(Error::WorkerLost(format!(
                            "tcp: worker {rank} {msg}"
                        )));
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        let msg = "link I/O thread died".to_string();
                        latch = Some(msg.clone());
                        break Err(Error::WorkerLost(format!(
                            "tcp: worker {rank}: {msg}"
                        )));
                    }
                };
                *wire_bytes += batch.bytes;
                if let Ok(b) = Arc::try_unwrap(frame) {
                    *enc = b;
                }
                break batch
                    .replies
                    .into_iter()
                    .next()
                    .unwrap_or_else(|| {
                        Err(Error::Runtime("link returned no reply".into()))
                    })
                    .map_err(|e| match e {
                        Error::WorkerLost(msg) => Error::WorkerLost(format!(
                            "tcp: worker {rank}: {msg}"
                        )),
                        e => Error::Runtime(format!("tcp: worker {rank}: {e}")),
                    });
            },
            LinkIo::Inline(stream) => loop {
                if let Err(e) = stream.write_all(enc.as_slice()) {
                    let msg = describe_io("send", &e);
                    latch = Some(msg.clone());
                    break Err(Error::WorkerLost(format!("tcp: worker {rank} {msg}")));
                }
                *wire_bytes += enc.len() as u64;
                break match wire::read_frame(stream, buf) {
                    Ok(Some(n)) => {
                        *wire_bytes += n as u64;
                        wire::decode_reply(buf).map_err(|e| {
                            Error::Runtime(format!(
                                "tcp: worker {rank} sent a malformed reply: {e}"
                            ))
                        })
                    }
                    Ok(None) => {
                        let msg = "connection closed mid-round".to_string();
                        latch = Some(msg.clone());
                        Err(Error::WorkerLost(format!("tcp: worker {rank}: {msg}")))
                    }
                    Err(Error::Io(e)) => {
                        let msg = describe_io("reply read", &e);
                        latch = Some(msg.clone());
                        Err(Error::WorkerLost(format!("tcp: worker {rank} {msg}")))
                    }
                    Err(e) => {
                        Err(Error::Runtime(format!("tcp: worker {rank}: {e}")))
                    }
                };
            },
            LinkIo::Dead(msg) => {
                Err(Error::WorkerLost(format!("tcp: worker {rank}: {msg}")))
            }
        };
        if let Some(msg) = latch {
            links[li].io = LinkIo::Dead(msg);
        }
        match result? {
            Reply::Err(e) if e.starts_with(RELAY_CHILD_LOST) => {
                Err(Error::WorkerLost(format!("worker {rank}: {e}")))
            }
            Reply::Err(e) => Err(Error::Runtime(format!("worker {rank}: {e}"))),
            r => Ok(r),
        }
    }

    // ---- gathers (shared by counted and instrumentation paths) -------

    fn gather_grad_loss_into(
        &mut self,
        w: &[f64],
        g: &mut [f64],
        use_codec: bool,
    ) -> Result<f64> {
        if use_codec && self.compressor.is_some() {
            return self.gather_grad_loss_compressed(w, g);
        }
        // Raw-slice encode into the pooled broadcast slot: byte-for-byte
        // the frame `Cmd::GradLoss` encodes, without materializing the
        // command value (`wire` pins the equivalence).
        wire::encode_grad_loss_cmd(w, bcast_slot(&mut self.bcast))?;
        g.fill(0.0);
        let mut loss = 0.0;
        // The fold borrows the weights by value-swap so it can run
        // inside `fold_round`'s `&mut self`; both takes are moves of
        // the Vec header, not allocations.
        let eff = std::mem::take(&mut self.eff_weights);
        let res = self.fold_round(&mut |i, r| match r {
            Reply::VecScalar(gi, li) if gi.len() == g.len() => {
                ops::axpy(eff[i], &gi, g);
                loss += eff[i] * li;
                Ok(())
            }
            _ => Err(Error::Runtime(format!("worker {i}: unexpected reply type"))),
        });
        self.eff_weights = eff;
        res?;
        Ok(loss)
    }

    // ---- compressed rounds ------------------------------------------

    /// Compressed gradient+loss round: one `CompressedVec` frame
    /// broadcast to every link, compressed replies decoded through the
    /// leader's scratch and folded in rank order exactly like the
    /// uncompressed gather. Tracks the signed raw-vs-actual byte delta
    /// for `payload_bytes_raw`.
    fn gather_grad_loss_compressed(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        let Some(comp) = self.compressor.as_mut() else {
            return Err(Error::Runtime(
                "compressed gather without a compressor".into(),
            ));
        };
        let cmd = Cmd::CompressedVec(Arc::new(comp.grad_cmd(w)));
        let buf = bcast_slot(&mut self.bcast);
        wire::encode_command(&cmd, buf)?;
        let raw_cmd = compress::raw_cmd_frame_len(CompressedOp::GradLoss, self.d) as i64;
        self.payload_raw_extra +=
            (raw_cmd - self.bcast.len() as i64) * self.links.len() as i64;
        let raw_rep =
            compress::raw_reply_frame_len(CompressedOp::GradLoss, self.d) as i64;
        g.fill(0.0);
        let mut loss = 0.0;
        let mut extra = 0i64;
        let mut dec = std::mem::take(&mut self.dec);
        let eff = std::mem::take(&mut self.eff_weights);
        let res = self.fold_round(&mut |i, r| match r {
            Reply::CompressedVec(cr) if cr.vec.dim() == g.len() && cr.loss.is_some() => {
                extra += raw_rep - cr.frame_len() as i64;
                cr.vec.decode_into(&mut dec);
                ops::axpy(eff[i], &dec, g);
                loss += eff[i] * cr.loss.unwrap_or(0.0);
                Ok(())
            }
            _ => Err(Error::Runtime(format!("worker {i}: unexpected reply type"))),
        });
        self.dec = dec;
        self.eff_weights = eff;
        self.payload_raw_extra += extra;
        res.map(|_| loss)
    }

    /// Compressed DANE local-solve round; the iterate average keeps the
    /// paper's unweighted 1/|alive| fold.
    fn dane_round_compressed(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
        out: &mut [f64],
    ) -> Result<()> {
        let Some(comp) = self.compressor.as_mut() else {
            return Err(Error::Runtime(
                "compressed round without a compressor".into(),
            ));
        };
        let cmd = Cmd::CompressedVec(Arc::new(comp.solve_cmd(w_prev, g, eta, mu)));
        let buf = bcast_slot(&mut self.bcast);
        wire::encode_command(&cmd, buf)?;
        let raw_cmd =
            compress::raw_cmd_frame_len(CompressedOp::DaneSolve, self.d) as i64;
        self.payload_raw_extra +=
            (raw_cmd - self.bcast.len() as i64) * self.links.len() as i64;
        let raw_rep =
            compress::raw_reply_frame_len(CompressedOp::DaneSolve, self.d) as i64;
        out.fill(0.0);
        let inv = 1.0 / self.n_alive as f64;
        let mut extra = 0i64;
        let mut dec = std::mem::take(&mut self.dec);
        let res = self.fold_round(&mut |i, r| match r {
            Reply::CompressedVec(cr) if cr.vec.dim() == out.len() && cr.loss.is_none() => {
                extra += raw_rep - cr.frame_len() as i64;
                cr.vec.decode_into(&mut dec);
                ops::axpy(inv, &dec, out);
                Ok(())
            }
            _ => Err(Error::Runtime(format!("worker {i}: unexpected reply type"))),
        });
        self.dec = dec;
        self.payload_raw_extra += extra;
        res
    }

    fn gather_loss(&mut self, w: &[f64]) -> Result<f64> {
        wire::encode_loss_cmd(w, bcast_slot(&mut self.bcast))?;
        let mut loss = 0.0;
        let eff = std::mem::take(&mut self.eff_weights);
        let res = self.fold_round(&mut |i, r| match r {
            Reply::Scalar(l) => {
                loss += eff[i] * l;
                Ok(())
            }
            _ => Err(Error::Runtime(format!("worker {i}: unexpected reply type"))),
        });
        self.eff_weights = eff;
        res?;
        Ok(loss)
    }
}

/// Mutable access to the pooled broadcast-frame slot. In steady state
/// every link released its clone when its round write completed, the
/// `Arc` is unique again, and the existing buffer is reused in place; a
/// still-shared slot (a latched-dead link's orphaned I/O thread can
/// hold its clone indefinitely) is replaced with a fresh buffer rather
/// than blocked on — never a panic, never a copy of the stale frame
/// (the encoder clears the buffer before writing anyway).
fn bcast_slot(slot: &mut Arc<Vec<u8>>) -> &mut Vec<u8> {
    if Arc::get_mut(slot).is_none() {
        *slot = Arc::new(Vec::new());
    }
    // unique by construction here, so make_mut never clones
    Arc::make_mut(slot)
}

fn fail_ranks(gather: &mut RankGather, ranks: &[usize], msg: &str) {
    for &r in ranks {
        gather.put(r, Err(Error::WorkerLost(format!("tcp: worker {r}: {msg}"))));
    }
}

fn describe_io(what: &str, e: &std::io::Error) -> String {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => {
            format!("wedged: {what} timed out")
        }
        _ => format!("{what} failed: {e}"),
    }
}

fn io_err(i: usize, what: &str, e: &std::io::Error) -> Error {
    Error::Runtime(format!("tcp: worker {i} {}", describe_io(what, e)))
}

fn configure_stream(s: &TcpStream, i: usize, timeout: Duration) -> Result<()> {
    s.set_nodelay(true)
        .map_err(|e| Error::Runtime(format!("tcp: worker {i} set_nodelay: {e}")))?;
    s.set_read_timeout(Some(timeout))
        .map_err(|e| Error::Runtime(format!("tcp: worker {i} read timeout: {e}")))?;
    s.set_write_timeout(Some(timeout))
        .map_err(|e| Error::Runtime(format!("tcp: worker {i} write timeout: {e}")))?;
    Ok(())
}

/// Read one setup ack (`Reply::Scalar`) during bring-up.
fn read_setup_ack(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    i: usize,
    what: &str,
) -> Result<u64> {
    let got = match wire::read_frame(stream, buf) {
        Ok(Some(total)) => total as u64,
        Ok(None) => {
            return Err(Error::Runtime(format!(
                "tcp: worker {i} closed the connection during {what}"
            )))
        }
        Err(Error::Io(e)) => return Err(io_err(i, "ack read", &e)),
        Err(e) => return Err(Error::Runtime(format!("tcp: worker {i}: {e}"))),
    };
    match wire::decode_reply(buf) {
        Ok(Reply::Scalar(_)) => Ok(got),
        Ok(Reply::Err(e)) => Err(Error::Runtime(format!("worker {i}: {e}"))),
        Ok(_) => Err(Error::Runtime(format!("tcp: worker {i}: unexpected {what} ack"))),
        Err(e) => Err(Error::Runtime(format!(
            "tcp: worker {i} sent a malformed {what} ack: {e}"
        ))),
    }
}

/// The socket-owning I/O actor of the parallel star / tree root link:
/// one write + `expect` reads per round, every transport failure turned
/// into per-reply errors so the leader's gather always drains. A dead
/// socket stays dead (every later round errors immediately).
fn spawn_link_io(mut stream: TcpStream, root: usize) -> LinkIo {
    let (job_tx, job_rx) = round_channel::<LinkJob>();
    let (batch_tx, batch_rx) = round_channel::<LinkBatch>();
    let builder = std::thread::Builder::new().name(format!("dane-link-{root}"));
    let join = super::must_spawn(builder, move || {
            let mut frame = Vec::new();
            let mut dead: Option<String> = None;
            while let Ok(LinkJob { frame: out, expect }) = job_rx.recv() {
                let mut bytes = 0u64;
                let mut replies: Vec<Result<Reply>> = Vec::with_capacity(expect);
                if dead.is_none() {
                    match stream.write_all(out.as_slice()) {
                        Ok(()) => bytes += out.len() as u64,
                        Err(e) => dead = Some(describe_io("send", &e)),
                    }
                }
                drop(out); // release the leader's encode buffer promptly
                for _ in 0..expect {
                    if let Some(msg) = &dead {
                        replies.push(Err(Error::WorkerLost(msg.clone())));
                        continue;
                    }
                    match wire::read_frame(&mut stream, &mut frame) {
                        Ok(Some(n)) => {
                            bytes += n as u64;
                            replies.push(wire::decode_reply(&frame).map_err(|e| {
                                Error::Runtime(format!("malformed reply: {e}"))
                            }));
                        }
                        Ok(None) => {
                            let msg = "connection closed mid-round".to_string();
                            replies.push(Err(Error::WorkerLost(msg.clone())));
                            dead = Some(msg);
                        }
                        Err(Error::Io(e)) => {
                            let msg = describe_io("reply read", &e);
                            replies.push(Err(Error::WorkerLost(msg.clone())));
                            dead = Some(msg);
                        }
                        Err(e) => {
                            let msg = e.to_string();
                            replies.push(Err(Error::Runtime(msg.clone())));
                            dead = Some(msg);
                        }
                    }
                }
                if batch_tx.send(LinkBatch { replies, bytes }).is_err() {
                    break; // leader gone
                }
            }
    });
    LinkIo::Thread { tx: job_tx, rx: batch_rx, join: Some(join) }
}

/// Process-wide worker-binary override set by [`set_worker_binary`].
/// Tests use this instead of `std::env::set_var("DANE_WORKER_BIN", …)`
/// so Miri/TSan never observe a `setenv`/`getenv` race between threads.
static WORKER_BIN_OVERRIDE: std::sync::OnceLock<PathBuf> = std::sync::OnceLock::new();

/// Point every subsequently spawned `TcpCluster` at `bin` as the worker
/// executable. First caller wins; later calls (e.g. one per test) are
/// no-ops, which is exactly what concurrent tests in one process want.
/// Takes precedence over the `DANE_WORKER_BIN` environment variable,
/// which remains the CLI-facing knob.
pub fn set_worker_binary(bin: impl Into<PathBuf>) {
    let _ = WORKER_BIN_OVERRIDE.set(bin.into());
}

fn worker_binary() -> Result<PathBuf> {
    if let Some(p) = WORKER_BIN_OVERRIDE.get() {
        return Ok(p.clone());
    }
    if let Ok(p) = std::env::var("DANE_WORKER_BIN") {
        return Ok(PathBuf::from(p));
    }
    std::env::current_exe()
        .map_err(|e| Error::Runtime(format!("tcp: cannot locate worker binary: {e}")))
}

/// Parse the `listening on <addr>` line a worker announces on stdout.
fn parse_listen_line(line: &str) -> Option<&str> {
    let addr = line.trim().strip_prefix("listening on ")?;
    if addr.is_empty() {
        None
    } else {
        Some(addr)
    }
}

fn spawn_worker_process(
    bin: &PathBuf,
    i: usize,
    announce_timeout: Duration,
) -> Result<(Child, String)> {
    let mut child = std::process::Command::new(bin)
        .args(["worker", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .map_err(|e| {
            Error::Runtime(format!("tcp: spawn worker {i} ({}): {e}", bin.display()))
        })?;
    let Some(stdout) = child.stdout.take() else {
        let _ = child.kill();
        let _ = child.wait();
        return Err(Error::Runtime(format!("tcp: worker {i}: no stdout pipe")));
    };
    // Read the announce line on a helper thread so a child that never
    // prints (wrong binary, wedged startup) surfaces as an error within
    // the io timeout instead of hanging bring-up — the pipe read itself
    // has no timeout facility. Killing the child below unblocks the
    // helper (its read returns EOF), so it never lingers.
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let mut line = String::new();
        let res = BufReader::new(stdout).read_line(&mut line).map(|_| line);
        let _ = tx.send(res);
    });
    let line = match rx.recv_timeout(announce_timeout) {
        Ok(Ok(line)) => line,
        Ok(Err(_)) | Err(_) => String::new(),
    };
    match parse_listen_line(&line).map(str::to_string) {
        Some(a) => Ok((child, a)),
        None => {
            let _ = child.kill();
            let _ = child.wait();
            Err(Error::Runtime(format!(
                "tcp: worker {i} did not announce its address within \
                 {announce_timeout:?} (got {line:?})"
            )))
        }
    }
}

impl Drop for TcpCluster {
    fn drop(&mut self) {
        // Shut the sockets first: a link I/O thread stuck mid-read
        // returns immediately instead of waiting out its socket
        // timeout, and externally-launched workers see EOF at a frame
        // boundary, end the session cleanly and return to accepting
        // the next leader (in tree mode the EOF cascades down the relay
        // links). Self-hosted children are killed and reaped so no
        // zombies outlive the cluster.
        self.teardown_links();
        kill_procs(&mut self.procs);
    }
}

impl Cluster for TcpCluster {
    fn m(&self) -> usize {
        self.weights.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn objective(&self) -> Arc<dyn Objective> {
        self.obj.clone()
    }

    fn grad_and_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let mut g = vec![0.0; self.d];
        let loss = self.grad_and_loss_into(w, &mut g)?;
        Ok((g, loss))
    }

    fn grad_and_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        let loss = self.gather_grad_loss_into(w, g, true)?;
        let m = self.m();
        self.comm.count_round(m, self.d + 1);
        Ok(loss)
    }

    fn loss_only(&mut self, w: &[f64]) -> Result<f64> {
        let loss = self.gather_loss(w)?;
        let m = self.m();
        self.comm.count_round(m, 1);
        Ok(loss)
    }

    fn dane_round(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        let mut acc = vec![0.0; self.d];
        self.dane_round_into(w_prev, g, eta, mu, &mut acc)?;
        Ok(acc)
    }

    fn dane_round_into(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
        out: &mut [f64],
    ) -> Result<()> {
        if self.compressor.is_some() {
            self.dane_round_compressed(w_prev, g, eta, mu, out)?;
            let m = self.m();
            self.comm.count_round(m, self.d);
            return Ok(());
        }
        wire::encode_dane_solve_cmd(w_prev, g, eta, mu, bcast_slot(&mut self.bcast))?;
        out.fill(0.0);
        // paper step (*): unweighted average in rank order; under a
        // degraded quorum it's the average over the surviving solvers
        let inv = 1.0 / self.n_alive as f64;
        self.fold_round(&mut |i, r| match r {
            Reply::Vec(wi) if wi.len() == out.len() => {
                ops::axpy(inv, &wi, out);
                Ok(())
            }
            _ => Err(Error::Runtime(format!("worker {i}: unexpected reply type"))),
        })?;
        let m = self.m();
        self.comm.count_round(m, self.d);
        Ok(())
    }

    fn dane_round_first(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        let solve = Cmd::DaneSolve {
            w_prev: Arc::new(w_prev.to_vec()),
            g: Arc::new(g.to_vec()),
            eta,
            mu,
            out: Vec::new(),
        };
        // Under the tree, a bare compute frame would be relayed as a
        // broadcast; the For envelope keeps it point-to-point (worker 0
        // heads the first root link, so it never actually relays).
        let first = (0..self.dead.len())
            .find(|&r| !self.dead[r])
            .ok_or_else(|| Error::WorkerLost("no alive workers".into()))?;
        let cmd = if self.tree_links {
            Cmd::For { rank: first, inner: Box::new(solve) }
        } else {
            solve
        };
        wire::encode_command(&cmd, &mut self.enc)?;
        let w1 = match self.fetch_single(first)? {
            Reply::Vec(w) if w.len() == self.d => w,
            _ => return Err(self.unexpected(first)),
        };
        let m = self.m();
        self.comm.count_round(m, self.d);
        Ok(w1)
    }

    fn prox_all(
        &mut self,
        targets: &[Vec<f64>],
        rho: f64,
    ) -> Result<Vec<Option<Vec<f64>>>> {
        assert_eq!(targets.len(), self.m());
        let replies = if self.tree_links {
            // One ProxAll frame relays down the tree; each worker picks
            // its own target by rank.
            wire::encode_command(
                &Cmd::ProxAll { targets: targets.to_vec(), rho },
                &mut self.enc,
            )?;
            self.broadcast_round()?
        } else {
            // Star links: per-worker frames, keyed by the rank each
            // link serves (links cover only the alive ranks).
            let ranks: Vec<usize> = self.links.iter().map(|l| l.ranks[0]).collect();
            let mut frames = Vec::with_capacity(ranks.len());
            for &r in &ranks {
                wire::encode_command(
                    &Cmd::Prox { v: targets[r].clone(), rho },
                    &mut self.enc,
                )?;
                frames.push(Arc::new(self.enc.clone()));
            }
            self.dispatch(frames)?
        };
        let mut out: Vec<Option<Vec<f64>>> = (0..self.m()).map(|_| None).collect();
        for (i, r) in replies.into_iter().enumerate() {
            match r {
                None => {}
                Some(Reply::Vec(w)) => out[i] = Some(w),
                Some(_) => return Err(self.unexpected(i)),
            }
        }
        Ok(out)
    }

    fn local_erms(
        &mut self,
        subsample: Option<(f64, u64)>,
    ) -> Result<(Vec<Option<Vec<f64>>>, Option<Vec<Option<Vec<f64>>>>)> {
        wire::encode_command(&Cmd::Erm { subsample }, &mut self.enc)?;
        let replies = self.broadcast_round()?;
        let m = self.m();
        let mut full: Vec<Option<Vec<f64>>> = (0..m).map(|_| None).collect();
        let mut subs: Vec<Option<Vec<f64>>> = (0..m).map(|_| None).collect();
        let mut any_sub = false;
        for (i, r) in replies.into_iter().enumerate() {
            match r {
                None => {}
                Some(Reply::VecPair(f, s)) => {
                    full[i] = Some(f);
                    if let Some(s) = s {
                        subs[i] = Some(s);
                        any_sub = true;
                    }
                }
                Some(_) => return Err(self.unexpected(i)),
            }
        }
        Ok((full, if any_sub { Some(subs) } else { None }))
    }

    fn allreduce_mean_vecs(&mut self, vecs: &[Vec<f64>]) -> Result<Vec<f64>> {
        let mut out = vec![0.0; self.d];
        let views: Vec<&[f64]> = vecs.iter().map(|v| v.as_slice()).collect();
        self.comm.allreduce_mean(&views, &mut out);
        Ok(out)
    }

    fn avg_row_sq_norm(&mut self) -> Result<f64> {
        if let Some(v) = self.row_sq {
            return Ok(v);
        }
        wire::encode_command(&Cmd::RowSq, bcast_slot(&mut self.bcast))?;
        let mut total = 0.0;
        let eff = std::mem::take(&mut self.eff_weights);
        let res = self.fold_round(&mut |i, r| match r {
            Reply::Scalar(v) => {
                total += eff[i] * v;
                Ok(())
            }
            _ => Err(Error::Runtime(format!("worker {i}: unexpected reply type"))),
        });
        self.eff_weights = eff;
        res?;
        let m = self.m();
        self.comm.count_round(m, 1);
        self.row_sq = Some(total);
        Ok(total)
    }

    fn eval_loss(&mut self, w: &[f64]) -> Result<f64> {
        self.gather_loss(w)
    }

    fn eval_grad_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let mut g = vec![0.0; self.d];
        // instrumentation path: always uncompressed, full-precision
        let loss = self.gather_grad_loss_into(w, &mut g, false)?;
        Ok((g, loss))
    }

    fn comm_stats(&self) -> CommStats {
        let mut s = self.comm.stats().clone();
        s.wire_bytes = self.wire_bytes;
        s.payload_bytes_raw = self.wire_bytes.saturating_add_signed(self.payload_raw_extra);
        s.startup_bytes = self.startup_bytes;
        s.alive_workers = self.n_alive as u64;
        s
    }

    fn reset_comm(&mut self) {
        self.comm.reset();
        self.wire_bytes = 0;
        self.payload_raw_extra = 0;
        // startup_bytes survives: it is a one-time data-distribution
        // cost, not per-window round traffic.
    }

    fn alive(&self) -> usize {
        self.n_alive
    }

    fn recover(&mut self, respawn: bool) -> Result<usize> {
        self.recover_impl(respawn)
    }

    fn restore_comm(&mut self, stats: &CommStats) {
        self.comm.restore(stats);
        self.wire_bytes = stats.wire_bytes;
        self.payload_raw_extra =
            stats.payload_bytes_raw as i64 - stats.wire_bytes as i64;
        self.startup_bytes = stats.startup_bytes;
    }

    fn fault_kill_worker(&mut self, rank: usize) {
        self.kill_worker(rank);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listen_line_parses() {
        assert_eq!(
            parse_listen_line("listening on 127.0.0.1:4471\n"),
            Some("127.0.0.1:4471")
        );
        assert_eq!(parse_listen_line("listening on "), None);
        assert_eq!(parse_listen_line("warming up"), None);
    }

}
