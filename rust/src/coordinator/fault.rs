//! Fault supervision and deterministic fault injection for the
//! cluster collective surface.
//!
//! Two decorators over `dyn Cluster` live here:
//!
//! * [`SupervisedCluster`] — the production-side supervisor. Every
//!   worker-touching collective runs under the configured
//!   [`FaultPolicy`]: `fail_fast` propagates the first
//!   [`Error::WorkerLost`] unchanged (the pre-fault behavior),
//!   `respawn` sleeps a capped exponential backoff with deterministic
//!   seeded jitter and asks the engine to [`Cluster::recover`] at full
//!   strength before retrying the failed round, and `degrade`
//!   quarantines the dead ranks and retries over the survivors as long
//!   as the quorum holds. Compute errors (a worker *answered* with an
//!   error) stay hard under every policy — retrying a deterministic
//!   failure cannot help.
//!
//! * [`FaultInjectCluster`] — the test harness for the crate's
//!   error-propagation contract: it simulates a worker dying at a
//!   chosen point in the run, and every algorithm must surface the
//!   injected failure as an [`super::AlgoError`] carrying the
//!   trace-so-far — never a panic (`rust/tests/fault_injection.rs`
//!   runs the whole matrix on both engines). A *transient* injector
//!   additionally lets a recovery succeed, modeling a crash whose
//!   respawn works.
//!
//! Leader-local operations (`allreduce_mean_vecs` of already-gathered
//! vectors, `comm_stats`, dimensions) do not touch workers and pass
//! through unsupervised and uncounted.

use super::Cluster;
use crate::comm::CommStats;
use crate::config::FaultPolicy;
use crate::loss::Objective;
use crate::util::Rng64;
use crate::{Error, Result};
use std::sync::Arc;
use std::time::Duration;

/// Longest single backoff sleep, whatever the exponent says.
const MAX_BACKOFF_MS: u64 = 10_000;

/// Policy-driven retry/respawn/degrade supervision over any engine.
///
/// The driver wraps the built engine in this decorator whenever the
/// config's fault policy is not `fail_fast` (and for `fail_fast` too —
/// the wrapper is transparent there, so the fault-free trace stays
/// bit-identical under every policy).
pub struct SupervisedCluster {
    inner: Box<dyn Cluster>,
    policy: FaultPolicy,
    /// Deterministic jitter stream (seed discipline: `cfg.seed + 3`).
    rng: Rng64,
    recoveries: u64,
    /// Chaos hook: SIGKILL worker `.1` right before worker-touching
    /// call number `.0` (1-based). Drives the CI chaos-smoke job.
    chaos_kill: Option<(u64, usize)>,
    calls: u64,
}

impl SupervisedCluster {
    pub fn new(inner: Box<dyn Cluster>, policy: FaultPolicy, jitter_seed: u64) -> Self {
        SupervisedCluster {
            inner,
            policy,
            rng: Rng64::seed_from_u64(jitter_seed),
            recoveries: 0,
            chaos_kill: None,
            calls: 0,
        }
    }

    /// Arm the chaos hook: kill worker `rank` immediately before the
    /// `call`-th worker-touching collective call (1-based). Fires once.
    pub fn chaos_kill_at(mut self, call: u64, rank: usize) -> Self {
        self.chaos_kill = Some((call, rank));
        self
    }

    /// Successful recoveries (respawns/redials or quorum degradations)
    /// so far.
    pub fn recoveries(&self) -> u64 {
        self.recoveries
    }

    fn sleep_backoff(&mut self, backoff_ms: u64, attempt: u32) {
        let exp = attempt.saturating_sub(1).min(6);
        let base = backoff_ms.saturating_mul(1u64 << exp).min(MAX_BACKOFF_MS);
        let jitter = (base as f64 * 0.1 * self.rng.f64()) as u64;
        let ms = base.saturating_add(jitter);
        if ms > 0 {
            std::thread::sleep(Duration::from_millis(ms));
        }
    }

    /// Run one worker-touching collective under the policy: retry the
    /// whole round after each successful recovery, so the leader never
    /// folds a half-answered round.
    fn with_retry<T>(
        &mut self,
        mut op: impl FnMut(&mut dyn Cluster) -> Result<T>,
    ) -> Result<T> {
        self.calls += 1;
        if let Some((at, rank)) = self.chaos_kill {
            if self.calls == at {
                self.inner.fault_kill_worker(rank);
                self.chaos_kill = None;
            }
        }
        let mut attempt: u32 = 0;
        loop {
            let lost = match op(self.inner.as_mut()) {
                Ok(v) => return Ok(v),
                Err(Error::WorkerLost(msg)) => msg,
                // compute errors, config errors, quorum loss: hard
                Err(e) => return Err(e),
            };
            match self.policy {
                FaultPolicy::FailFast => return Err(Error::WorkerLost(lost)),
                FaultPolicy::Respawn { max_retries, backoff_ms } => {
                    // consume attempts until one recovery brings the
                    // cluster back to full strength, then retry the op
                    loop {
                        attempt += 1;
                        if attempt > max_retries {
                            return Err(Error::WorkerLost(format!(
                                "gave up after {max_retries} respawn attempts: {lost}"
                            )));
                        }
                        self.sleep_backoff(backoff_ms, attempt);
                        if self.inner.recover(true).is_ok() {
                            self.recoveries += 1;
                            break;
                        }
                    }
                }
                FaultPolicy::Degrade { min_quorum } => {
                    attempt += 1;
                    // each failed attempt quarantines at least one rank
                    // (or heals transiently); m+1 attempts bound the loop
                    if attempt as usize > self.inner.m() + 1 {
                        return Err(Error::WorkerLost(format!(
                            "degrade retries exhausted: {lost}"
                        )));
                    }
                    let alive = self.inner.recover(false)?;
                    self.recoveries += 1;
                    if alive < min_quorum {
                        return Err(Error::Runtime(format!(
                            "quorum lost: {alive} alive < min_quorum \
                             {min_quorum}: {lost}"
                        )));
                    }
                }
            }
        }
    }
}

impl Cluster for SupervisedCluster {
    fn m(&self) -> usize {
        self.inner.m()
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn objective(&self) -> Arc<dyn Objective> {
        self.inner.objective()
    }

    fn grad_and_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        self.with_retry(|c| c.grad_and_loss(w))
    }

    fn grad_and_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        self.with_retry(|c| c.grad_and_loss_into(w, g))
    }

    fn loss_only(&mut self, w: &[f64]) -> Result<f64> {
        self.with_retry(|c| c.loss_only(w))
    }

    fn dane_round(&mut self, w_prev: &[f64], g: &[f64], eta: f64, mu: f64) -> Result<Vec<f64>> {
        self.with_retry(|c| c.dane_round(w_prev, g, eta, mu))
    }

    fn dane_round_into(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
        out: &mut [f64],
    ) -> Result<()> {
        self.with_retry(|c| c.dane_round_into(w_prev, g, eta, mu, out))
    }

    fn dane_round_first(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        self.with_retry(|c| c.dane_round_first(w_prev, g, eta, mu))
    }

    fn prox_all(
        &mut self,
        targets: &[Vec<f64>],
        rho: f64,
    ) -> Result<Vec<Option<Vec<f64>>>> {
        self.with_retry(|c| c.prox_all(targets, rho))
    }

    fn local_erms(
        &mut self,
        subsample: Option<(f64, u64)>,
    ) -> Result<(Vec<Option<Vec<f64>>>, Option<Vec<Option<Vec<f64>>>>)> {
        self.with_retry(|c| c.local_erms(subsample))
    }

    fn allreduce_mean_vecs(&mut self, vecs: &[Vec<f64>]) -> Result<Vec<f64>> {
        self.inner.allreduce_mean_vecs(vecs)
    }

    fn avg_row_sq_norm(&mut self) -> Result<f64> {
        self.with_retry(|c| c.avg_row_sq_norm())
    }

    fn eval_loss(&mut self, w: &[f64]) -> Result<f64> {
        self.with_retry(|c| c.eval_loss(w))
    }

    fn eval_grad_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        self.with_retry(|c| c.eval_grad_loss(w))
    }

    fn comm_stats(&self) -> CommStats {
        let mut s = self.inner.comm_stats();
        s.recoveries = self.recoveries;
        s
    }

    fn reset_comm(&mut self) {
        self.inner.reset_comm();
    }

    fn alive(&self) -> usize {
        self.inner.alive()
    }

    fn recover(&mut self, respawn: bool) -> Result<usize> {
        self.inner.recover(respawn)
    }

    fn restore_comm(&mut self, stats: &CommStats) {
        self.recoveries = stats.recoveries;
        self.inner.restore_comm(stats);
    }

    fn fault_kill_worker(&mut self, rank: usize) {
        self.inner.fault_kill_worker(rank);
    }

    fn enable_recovery(
        &mut self,
        ds: &crate::data::Dataset,
        shard_seed: u64,
        gram_threads: Option<usize>,
    ) {
        self.inner.enable_recovery(ds, shard_seed, gram_threads);
    }
}

/// A cluster in which worker `fail_worker` "dies" on the
/// `fail_at_call`-th worker-touching collective call (1-based).
pub struct FaultInjectCluster {
    inner: Box<dyn Cluster>,
    /// Label only: which worker the injected error *reports* as dead.
    /// Both engines fail the whole round on any worker death (the
    /// threaded engine drains all replies and surfaces the first
    /// error), so the wrapper models a failed round, not a per-worker
    /// degradation — the id never changes behavior.
    fail_worker: usize,
    fail_at_call: usize,
    /// Transient faults heal on the first recovery attempt: `recover`
    /// disarms the trigger and reports the inner cluster's strength
    /// without touching it (the simulated worker "respawned").
    transient: bool,
    calls: usize,
}

impl FaultInjectCluster {
    /// Wrap `inner`; the fault fires on worker-touching call number
    /// `fail_at_call` (1-based) and every call after it. A trigger of
    /// `usize::MAX` never fires (transparent passthrough).
    /// `fail_worker` only names the dead worker in the error message.
    pub fn new(inner: Box<dyn Cluster>, fail_worker: usize, fail_at_call: usize) -> Self {
        FaultInjectCluster { inner, fail_worker, fail_at_call, transient: false, calls: 0 }
    }

    /// Make the injected fault transient: the first `recover` call
    /// succeeds and disarms it.
    pub fn transient(mut self) -> Self {
        self.transient = true;
        self
    }

    /// Worker-touching calls observed so far.
    pub fn calls(&self) -> usize {
        self.calls
    }

    /// Whether the fault has fired.
    pub fn tripped(&self) -> bool {
        self.calls >= self.fail_at_call
    }

    fn tick(&mut self) -> Result<()> {
        self.calls += 1;
        if self.calls >= self.fail_at_call {
            return Err(Error::WorkerLost(format!(
                "injected fault: worker {} died (collective call {}, trigger {})",
                self.fail_worker, self.calls, self.fail_at_call
            )));
        }
        Ok(())
    }
}

impl Cluster for FaultInjectCluster {
    fn m(&self) -> usize {
        self.inner.m()
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn objective(&self) -> Arc<dyn Objective> {
        self.inner.objective()
    }

    fn grad_and_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        self.tick()?;
        self.inner.grad_and_loss(w)
    }

    fn grad_and_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        self.tick()?;
        self.inner.grad_and_loss_into(w, g)
    }

    fn loss_only(&mut self, w: &[f64]) -> Result<f64> {
        self.tick()?;
        self.inner.loss_only(w)
    }

    fn dane_round(&mut self, w_prev: &[f64], g: &[f64], eta: f64, mu: f64) -> Result<Vec<f64>> {
        self.tick()?;
        self.inner.dane_round(w_prev, g, eta, mu)
    }

    fn dane_round_into(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
        out: &mut [f64],
    ) -> Result<()> {
        self.tick()?;
        self.inner.dane_round_into(w_prev, g, eta, mu, out)
    }

    fn dane_round_first(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        self.tick()?;
        self.inner.dane_round_first(w_prev, g, eta, mu)
    }

    fn prox_all(
        &mut self,
        targets: &[Vec<f64>],
        rho: f64,
    ) -> Result<Vec<Option<Vec<f64>>>> {
        self.tick()?;
        self.inner.prox_all(targets, rho)
    }

    fn local_erms(
        &mut self,
        subsample: Option<(f64, u64)>,
    ) -> Result<(Vec<Option<Vec<f64>>>, Option<Vec<Option<Vec<f64>>>>)> {
        self.tick()?;
        self.inner.local_erms(subsample)
    }

    fn allreduce_mean_vecs(&mut self, vecs: &[Vec<f64>]) -> Result<Vec<f64>> {
        // Leader-local reduction of vectors already in hand — no worker
        // involvement, so the fault cannot fire here (the inner engine
        // may still fail it on its own terms).
        self.inner.allreduce_mean_vecs(vecs)
    }

    fn avg_row_sq_norm(&mut self) -> Result<f64> {
        self.tick()?;
        self.inner.avg_row_sq_norm()
    }

    fn eval_loss(&mut self, w: &[f64]) -> Result<f64> {
        self.tick()?;
        self.inner.eval_loss(w)
    }

    fn eval_grad_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        self.tick()?;
        self.inner.eval_grad_loss(w)
    }

    fn comm_stats(&self) -> CommStats {
        self.inner.comm_stats()
    }

    fn reset_comm(&mut self) {
        self.inner.reset_comm();
    }

    fn alive(&self) -> usize {
        self.inner.alive()
    }

    fn recover(&mut self, respawn: bool) -> Result<usize> {
        if self.transient && self.tripped() {
            self.fail_at_call = usize::MAX;
            return Ok(self.inner.alive());
        }
        self.inner.recover(respawn)
    }

    fn restore_comm(&mut self, stats: &CommStats) {
        self.inner.restore_comm(stats);
    }

    fn fault_kill_worker(&mut self, rank: usize) {
        self.inner.fault_kill_worker(rank);
    }

    fn enable_recovery(
        &mut self,
        ds: &crate::data::Dataset,
        shard_seed: u64,
        gram_threads: Option<usize>,
    ) {
        self.inner.enable_recovery(ds, shard_seed, gram_threads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SerialCluster;
    use crate::data::synthetic_fig2;
    use crate::loss::Ridge;

    fn wrapped(fail_at: usize) -> FaultInjectCluster {
        let ds = synthetic_fig2(64, 5, 0.005, 3);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        FaultInjectCluster::new(Box::new(SerialCluster::new(&ds, obj, 2, 1)), 1, fail_at)
    }

    #[test]
    fn transparent_before_trigger() {
        let ds = synthetic_fig2(64, 5, 0.005, 3);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let mut bare = SerialCluster::new(&ds, obj, 2, 1);
        let mut faulty = wrapped(usize::MAX);
        let w = vec![0.1; 5];
        let (g1, l1) = bare.grad_and_loss(&w).unwrap();
        let (g2, l2) = faulty.grad_and_loss(&w).unwrap();
        assert_eq!(g1, g2);
        assert_eq!(l1, l2);
        assert_eq!(faulty.calls(), 1);
        assert!(!faulty.tripped());
    }

    #[test]
    fn fires_at_trigger_and_stays_dead() {
        let mut c = wrapped(2);
        let w = vec![0.0; 5];
        assert!(c.grad_and_loss(&w).is_ok(), "call 1 precedes the trigger");
        let err = c.loss_only(&w).unwrap_err();
        assert!(err.to_string().contains("injected fault"), "{err}");
        assert!(matches!(err, Error::WorkerLost(_)), "recoverable class: {err}");
        assert!(c.tripped());
        // a dead worker stays dead: every later call fails too
        assert!(c.eval_loss(&w).is_err());
        assert!(c.dane_round(&w, &w, 1.0, 0.1).is_err());
    }

    #[test]
    fn leader_local_ops_never_fault() {
        let mut c = wrapped(1);
        let w = vec![0.0; 5];
        assert!(c.grad_and_loss(&w).is_err());
        // metadata and leader-side averaging still work on a dead cluster
        assert_eq!(c.m(), 2);
        assert_eq!(c.dim(), 5);
        let mean = c.allreduce_mean_vecs(&[vec![1.0; 5], vec![3.0; 5]]).unwrap();
        assert_eq!(mean, vec![2.0; 5]);
    }

    #[test]
    fn transient_fault_heals_on_recover() {
        let mut c = wrapped(1).transient();
        let w = vec![0.0; 5];
        assert!(c.grad_and_loss(&w).is_err());
        assert_eq!(c.recover(true).unwrap(), 2);
        let (_, l) = c.grad_and_loss(&w).unwrap();
        assert!(l.is_finite());
    }

    #[test]
    fn supervised_respawn_retries_transient_fault() {
        let ds = synthetic_fig2(64, 5, 0.005, 3);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let mut bare = SerialCluster::new(&ds, obj, 2, 1);
        let inner = wrapped(2).transient();
        let mut sup = SupervisedCluster::new(
            Box::new(inner),
            FaultPolicy::Respawn { max_retries: 3, backoff_ms: 0 },
            7,
        );
        let w = vec![0.1; 5];
        let (g0, l0) = bare.grad_and_loss(&w).unwrap();
        let (g1, l1) = sup.grad_and_loss(&w).unwrap(); // call 1: clean
        let (g2, l2) = sup.grad_and_loss(&w).unwrap(); // call 2: dies, respawns
        assert_eq!(g0, g1);
        assert_eq!(g1, g2);
        assert_eq!(l1, l2);
        assert_eq!(l0, l1);
        assert_eq!(sup.recoveries(), 1);
        assert_eq!(sup.comm_stats().recoveries, 1);
    }

    #[test]
    fn supervised_fail_fast_propagates() {
        let inner = wrapped(1).transient();
        let mut sup = SupervisedCluster::new(Box::new(inner), FaultPolicy::FailFast, 7);
        let err = sup.grad_and_loss(&[0.0; 5]).unwrap_err();
        assert!(err.to_string().contains("injected fault"), "{err}");
        assert_eq!(sup.recoveries(), 0);
    }

    #[test]
    fn supervised_respawn_gives_up_on_permanent_fault() {
        // non-transient: recover() delegates to SerialCluster, which
        // cannot recover, so every attempt is consumed
        let inner = wrapped(1);
        let mut sup = SupervisedCluster::new(
            Box::new(inner),
            FaultPolicy::Respawn { max_retries: 2, backoff_ms: 0 },
            7,
        );
        let err = sup.grad_and_loss(&[0.0; 5]).unwrap_err();
        assert!(err.to_string().contains("gave up after 2"), "{err}");
    }

    #[test]
    fn supervised_degrade_rejects_quorum_loss() {
        // transient heal keeps both workers alive, but the configured
        // quorum demands more than the cluster has
        let inner = wrapped(1).transient();
        let mut sup = SupervisedCluster::new(
            Box::new(inner),
            FaultPolicy::Degrade { min_quorum: 3 },
            7,
        );
        let err = sup.grad_and_loss(&[0.0; 5]).unwrap_err();
        assert!(err.to_string().contains("quorum lost"), "{err}");
    }

    #[test]
    fn supervised_degrade_continues_within_quorum() {
        let inner = wrapped(2).transient();
        let mut sup = SupervisedCluster::new(
            Box::new(inner),
            FaultPolicy::Degrade { min_quorum: 1 },
            7,
        );
        let w = vec![0.1; 5];
        assert!(sup.grad_and_loss(&w).is_ok());
        assert!(sup.grad_and_loss(&w).is_ok()); // dies, heals, retries
        assert_eq!(sup.recoveries(), 1);
    }
}
