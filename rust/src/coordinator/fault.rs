//! Deterministic fault injection for the cluster collective surface.
//!
//! [`FaultInjectCluster`] decorates any `dyn Cluster` and simulates a
//! worker dying at a chosen point in the run: the k-th *worker-touching*
//! collective call (counted and instrumentation rounds alike — a dead
//! machine cannot answer either) returns `Err` instead of delegating,
//! and every later call keeps failing, exactly like a real dead worker
//! under the threaded engine's drain-then-error protocol.
//!
//! This is the test harness for the crate's error-propagation contract:
//! every algorithm must surface the injected failure as an
//! [`super::AlgoError`] carrying the trace-so-far — never a panic
//! (`rust/tests/fault_injection.rs` runs the whole matrix on both
//! engines).
//!
//! Leader-local operations (`allreduce_mean_vecs` of already-gathered
//! vectors, `comm_stats`, dimensions) do not touch workers and pass
//! through uncounted.

use super::Cluster;
use crate::comm::CommStats;
use crate::loss::Objective;
use crate::{Error, Result};
use std::sync::Arc;

/// A cluster in which worker `fail_worker` "dies" on the
/// `fail_at_call`-th worker-touching collective call (1-based).
pub struct FaultInjectCluster {
    inner: Box<dyn Cluster>,
    /// Label only: which worker the injected error *reports* as dead.
    /// Both engines fail the whole round on any worker death (the
    /// threaded engine drains all replies and surfaces the first
    /// error), so the wrapper models a failed round, not a per-worker
    /// degradation — the id never changes behavior.
    fail_worker: usize,
    fail_at_call: usize,
    calls: usize,
}

impl FaultInjectCluster {
    /// Wrap `inner`; the fault fires on worker-touching call number
    /// `fail_at_call` (1-based) and every call after it. A trigger of
    /// `usize::MAX` never fires (transparent passthrough).
    /// `fail_worker` only names the dead worker in the error message.
    pub fn new(inner: Box<dyn Cluster>, fail_worker: usize, fail_at_call: usize) -> Self {
        FaultInjectCluster { inner, fail_worker, fail_at_call, calls: 0 }
    }

    /// Worker-touching calls observed so far.
    pub fn calls(&self) -> usize {
        self.calls
    }

    /// Whether the fault has fired.
    pub fn tripped(&self) -> bool {
        self.calls >= self.fail_at_call
    }

    fn tick(&mut self) -> Result<()> {
        self.calls += 1;
        if self.calls >= self.fail_at_call {
            return Err(Error::Runtime(format!(
                "injected fault: worker {} died (collective call {}, trigger {})",
                self.fail_worker, self.calls, self.fail_at_call
            )));
        }
        Ok(())
    }
}

impl Cluster for FaultInjectCluster {
    fn m(&self) -> usize {
        self.inner.m()
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn objective(&self) -> Arc<dyn Objective> {
        self.inner.objective()
    }

    fn grad_and_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        self.tick()?;
        self.inner.grad_and_loss(w)
    }

    fn grad_and_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        self.tick()?;
        self.inner.grad_and_loss_into(w, g)
    }

    fn loss_only(&mut self, w: &[f64]) -> Result<f64> {
        self.tick()?;
        self.inner.loss_only(w)
    }

    fn dane_round(&mut self, w_prev: &[f64], g: &[f64], eta: f64, mu: f64) -> Result<Vec<f64>> {
        self.tick()?;
        self.inner.dane_round(w_prev, g, eta, mu)
    }

    fn dane_round_into(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
        out: &mut [f64],
    ) -> Result<()> {
        self.tick()?;
        self.inner.dane_round_into(w_prev, g, eta, mu, out)
    }

    fn dane_round_first(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        self.tick()?;
        self.inner.dane_round_first(w_prev, g, eta, mu)
    }

    fn prox_all(&mut self, targets: &[Vec<f64>], rho: f64) -> Result<Vec<Vec<f64>>> {
        self.tick()?;
        self.inner.prox_all(targets, rho)
    }

    fn local_erms(
        &mut self,
        subsample: Option<(f64, u64)>,
    ) -> Result<(Vec<Vec<f64>>, Option<Vec<Vec<f64>>>)> {
        self.tick()?;
        self.inner.local_erms(subsample)
    }

    fn allreduce_mean_vecs(&mut self, vecs: &[Vec<f64>]) -> Result<Vec<f64>> {
        // Leader-local reduction of vectors already in hand — no worker
        // involvement, so the fault cannot fire here (the inner engine
        // may still fail it on its own terms).
        self.inner.allreduce_mean_vecs(vecs)
    }

    fn avg_row_sq_norm(&mut self) -> Result<f64> {
        self.tick()?;
        self.inner.avg_row_sq_norm()
    }

    fn eval_loss(&mut self, w: &[f64]) -> Result<f64> {
        self.tick()?;
        self.inner.eval_loss(w)
    }

    fn eval_grad_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        self.tick()?;
        self.inner.eval_grad_loss(w)
    }

    fn comm_stats(&self) -> CommStats {
        self.inner.comm_stats()
    }

    fn reset_comm(&mut self) {
        self.inner.reset_comm();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SerialCluster;
    use crate::data::synthetic_fig2;
    use crate::loss::Ridge;

    fn wrapped(fail_at: usize) -> FaultInjectCluster {
        let ds = synthetic_fig2(64, 5, 0.005, 3);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        FaultInjectCluster::new(Box::new(SerialCluster::new(&ds, obj, 2, 1)), 1, fail_at)
    }

    #[test]
    fn transparent_before_trigger() {
        let ds = synthetic_fig2(64, 5, 0.005, 3);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let mut bare = SerialCluster::new(&ds, obj, 2, 1);
        let mut faulty = wrapped(usize::MAX);
        let w = vec![0.1; 5];
        let (g1, l1) = bare.grad_and_loss(&w).unwrap();
        let (g2, l2) = faulty.grad_and_loss(&w).unwrap();
        assert_eq!(g1, g2);
        assert_eq!(l1, l2);
        assert_eq!(faulty.calls(), 1);
        assert!(!faulty.tripped());
    }

    #[test]
    fn fires_at_trigger_and_stays_dead() {
        let mut c = wrapped(2);
        let w = vec![0.0; 5];
        assert!(c.grad_and_loss(&w).is_ok(), "call 1 precedes the trigger");
        let err = c.loss_only(&w).unwrap_err();
        assert!(err.to_string().contains("injected fault"), "{err}");
        assert!(c.tripped());
        // a dead worker stays dead: every later call fails too
        assert!(c.eval_loss(&w).is_err());
        assert!(c.dane_round(&w, &w, 1.0, 0.1).is_err());
    }

    #[test]
    fn leader_local_ops_never_fault() {
        let mut c = wrapped(1);
        let w = vec![0.0; 5];
        assert!(c.grad_and_loss(&w).is_err());
        // metadata and leader-side averaging still work on a dead cluster
        assert_eq!(c.m(), 2);
        assert_eq!(c.dim(), 5);
        let mean = c.allreduce_mean_vecs(&[vec![1.0; 5], vec![3.0; 5]]).unwrap();
        assert_eq!(mean, vec![2.0; 5]);
    }
}
