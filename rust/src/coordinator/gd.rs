//! Distributed gradient descent and Nesterov-accelerated GD.
//!
//! The `O(L/lambda log 1/eps)` / `O(sqrt(L/lambda) log 1/eps)` baselines
//! of paper eq. (8). One allreduce per iteration: the averaged gradient;
//! every machine then applies the identical deterministic update, so no
//! second round is needed.
//!
//! The step size uses the trace bound
//! `L <= l''_max * E[||x||^2] + lambda` (one extra counted round to
//! average the squared row norms, once per run).

use super::{finish, AlgoOutcome, Cluster, RunCtx};
use crate::linalg::ops;
use crate::metrics::Trace;
use crate::Result;

/// Plain GD options.
#[derive(Debug, Clone, Copy, Default)]
pub struct GdOptions {
    /// Fixed step size; None = 1/L with L from the trace bound.
    pub step: Option<f64>,
}

/// Accelerated GD options.
#[derive(Debug, Clone, Copy, Default)]
pub struct AgdOptions {
    /// Fixed step size; None = 1/L with L from the trace bound.
    pub step: Option<f64>,
    /// Strong convexity estimate; None = objective's lambda.
    pub strong_convexity: Option<f64>,
}

/// Upper bound on the smoothness of phi via the data trace bound.
/// Costs ONE counted round when the step is not supplied; a dead worker
/// surfaces here as an error like every other round.
fn trace_bound_l(cluster: &mut dyn Cluster) -> Result<f64> {
    let obj = cluster.objective();
    let row_sq = cluster.avg_row_sq_norm()?;
    Ok(obj.scalar_smoothness() * row_sq + obj.lambda())
}

/// Run distributed gradient descent from w = 0. Cluster failures return
/// as an error carrying the trace-so-far — never a panic.
pub fn run_gd(cluster: &mut dyn Cluster, opts: &GdOptions, ctx: &RunCtx) -> AlgoOutcome {
    let mut w = vec![0.0; cluster.dim()];
    let mut trace = Trace::new();
    let mut converged = false;
    let res = gd_loop(cluster, opts, ctx, &mut w, &mut trace, &mut converged);
    finish("gd", res, w, trace, converged)
}

fn gd_loop(
    cluster: &mut dyn Cluster,
    opts: &GdOptions,
    ctx: &RunCtx,
    w: &mut Vec<f64>,
    trace: &mut Trace,
    converged: &mut bool,
) -> Result<()> {
    let obj = cluster.objective();
    let resume = ctx.ckpt.as_ref().and_then(|ck| ck.resume_for("gd"));
    // On resume the (counted) step-estimation round already ran before
    // the checkpoint; reuse its result instead of re-charging it.
    let step = match (&resume, opts.step) {
        (Some(c), _) => c
            .scalar("step")
            .ok_or_else(|| crate::Error::Runtime("checkpoint lacks step".into()))?,
        (None, Some(s)) => s,
        (None, None) => 1.0 / trace_bound_l(cluster)?,
    };
    let mut start = 0;
    if let Some(c) = resume {
        *w = c
            .vec("w")
            .ok_or_else(|| crate::Error::Runtime("checkpoint lacks iterate w".into()))?
            .to_vec();
        *trace = c.trace.clone();
        cluster.restore_comm(&c.comm);
        start = c.round as usize + 1;
    }
    let t0 = std::time::Instant::now();

    for iter in start..=ctx.max_rounds {
        let (g, loss) = if iter < ctx.max_rounds && !*converged {
            cluster.grad_and_loss(w)?
        } else {
            cluster.eval_grad_loss(w)?
        };
        let subopt = ctx.subopt(loss);
        trace.push(
            iter,
            loss,
            subopt,
            Some(ops::norm2(&g)),
            ctx.test_loss(obj.as_ref(), w),
            &cluster.comm_stats(),
            t0.elapsed().as_secs_f64(),
        );
        if subopt.map(|s| s < ctx.tol).unwrap_or(false) {
            *converged = true;
            break;
        }
        if iter == ctx.max_rounds {
            break;
        }
        ops::axpy(-step, &g, w);
        if let Some(ck) = &ctx.ckpt {
            ck.maybe_save(
                "gd",
                iter,
                &cluster.comm_stats(),
                &[("step", step)],
                &[("w", w.as_slice())],
                trace,
            )?;
        }
    }
    Ok(())
}

/// Run Nesterov-accelerated gradient descent (strongly convex variant,
/// momentum (sqrt(kappa)-1)/(sqrt(kappa)+1)) from w = 0. Cluster
/// failures return as an error carrying the trace-so-far.
pub fn run_agd(cluster: &mut dyn Cluster, opts: &AgdOptions, ctx: &RunCtx) -> AlgoOutcome {
    let mut w = vec![0.0; cluster.dim()];
    let mut trace = Trace::new();
    let mut converged = false;
    let res = agd_loop(cluster, opts, ctx, &mut w, &mut trace, &mut converged);
    finish("agd", res, w, trace, converged)
}

fn agd_loop(
    cluster: &mut dyn Cluster,
    opts: &AgdOptions,
    ctx: &RunCtx,
    w: &mut Vec<f64>,
    trace: &mut Trace,
    converged: &mut bool,
) -> Result<()> {
    let d = cluster.dim();
    let obj = cluster.objective();
    let resume = ctx.ckpt.as_ref().and_then(|ck| ck.resume_for("agd"));
    // On resume the (counted) smoothness-estimation round already ran
    // before the checkpoint; reuse the saved L instead of re-charging it.
    let l = match (&resume, opts.step) {
        (Some(c), _) => c
            .scalar("l")
            .ok_or_else(|| crate::Error::Runtime("checkpoint lacks smoothness l".into()))?,
        (None, Some(s)) => 1.0 / s,
        (None, None) => trace_bound_l(cluster)?,
    };
    let sc = opts.strong_convexity.unwrap_or_else(|| obj.lambda()).max(1e-300);
    let kappa = (l / sc).max(1.0);
    let momentum = (kappa.sqrt() - 1.0) / (kappa.sqrt() + 1.0);
    let step = 1.0 / l;

    let mut w_prev = vec![0.0; d];
    let mut lookahead = vec![0.0; d];
    let mut start = 0;
    if let Some(c) = resume {
        let restore = |name: &str| -> Result<Vec<f64>> {
            Ok(c.vec(name)
                .ok_or_else(|| crate::Error::Runtime(format!("checkpoint lacks {name}")))?
                .to_vec())
        };
        *w = restore("w")?;
        w_prev = restore("w_prev")?;
        lookahead = restore("lookahead")?;
        *trace = c.trace.clone();
        cluster.restore_comm(&c.comm);
        start = c.round as usize + 1;
    }
    let t0 = std::time::Instant::now();

    for iter in start..=ctx.max_rounds {
        // Gradient at the lookahead point drives the update; the trace
        // reports phi at w (the returned iterate).
        let (g, loss_look) = if iter < ctx.max_rounds && !*converged {
            cluster.grad_and_loss(&lookahead)?
        } else {
            cluster.eval_grad_loss(&lookahead)?
        };
        // instrumentation: loss at w itself
        let loss = if ops::dist2(w, &lookahead) == 0.0 {
            loss_look
        } else {
            cluster.eval_loss(w)?
        };
        let subopt = ctx.subopt(loss);
        trace.push(
            iter,
            loss,
            subopt,
            Some(ops::norm2(&g)),
            ctx.test_loss(obj.as_ref(), w),
            &cluster.comm_stats(),
            t0.elapsed().as_secs_f64(),
        );
        if subopt.map(|s| s < ctx.tol).unwrap_or(false) {
            *converged = true;
            break;
        }
        if iter == ctx.max_rounds {
            break;
        }
        // w_next = lookahead - step * g
        w_prev.copy_from_slice(w);
        for j in 0..d {
            w[j] = lookahead[j] - step * g[j];
        }
        for j in 0..d {
            lookahead[j] = w[j] + momentum * (w[j] - w_prev[j]);
        }
        if let Some(ck) = &ctx.ckpt {
            ck.maybe_save(
                "agd",
                iter,
                &cluster.comm_stats(),
                &[("l", l)],
                &[
                    ("w", w.as_slice()),
                    ("w_prev", w_prev.as_slice()),
                    ("lookahead", lookahead.as_slice()),
                ],
                trace,
            )?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SerialCluster;
    use crate::data::synthetic_fig2;
    use crate::loss::{Objective, Ridge};
    use crate::solver::erm_solve;
    use std::sync::Arc;

    fn setup(
        n: usize,
        d: usize,
        lam: f64,
    ) -> (SerialCluster, f64) {
        let ds = synthetic_fig2(n, d, lam / 2.0, 1);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        (SerialCluster::new(&ds, obj, 4, 2), phi_star)
    }

    #[test]
    fn gd_monotone_decrease() {
        // GD with step 1/L on an L-smooth convex objective descends every
        // iteration (descent lemma). Asserted up to the f64 noise floor
        // of the objective evaluation: phi = O(1) here, so suboptimality
        // differences below ~1e-14 are rounding, not ascent.
        let (mut cluster, phi_star) = setup(512, 8, 0.1);
        let ctx = RunCtx::new(50).with_reference(phi_star).with_tol(1e-30);
        let res = run_gd(&mut cluster, &GdOptions::default(), &ctx).unwrap();
        let s = res.trace.suboptimality();
        for w in s.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-12) + 1e-14,
                "{:?}",
                &s[..6.min(s.len())]
            );
        }
    }

    #[test]
    fn agd_beats_gd_on_rounds() {
        // mildly ill-conditioned quadratic: AGD should hit tol in fewer
        // iterations than GD.
        let (mut c1, phi_star) = setup(2048, 24, 0.01);
        let (mut c2, _) = setup(2048, 24, 0.01);
        let ctx = RunCtx::new(400).with_reference(phi_star).with_tol(1e-6);
        let gd = run_gd(&mut c1, &GdOptions::default(), &ctx).unwrap();
        let agd = run_agd(&mut c2, &AgdOptions::default(), &ctx).unwrap();
        assert!(agd.converged, "agd: {:?}", agd.trace.last_suboptimality());
        // kappa ~ L/lambda ~ 250 here: GD needs O(kappa log 1/eps) ~
        // thousands of rounds (eq. 8) and cannot finish inside the 400
        // budget, while AGD's O(sqrt(kappa) log 1/eps) ~ 200 fits — the
        // gap is structural, not a tuning accident.
        let gd_rounds = gd.trace.rounds_to_tol(1e-6).unwrap_or(usize::MAX);
        let agd_rounds = agd.trace.rounds_to_tol(1e-6).unwrap_or(usize::MAX);
        assert!(
            agd_rounds < gd_rounds,
            "agd {agd_rounds} vs gd {gd_rounds}"
        );
    }

    #[test]
    fn gd_counts_one_round_per_iteration() {
        let (mut cluster, _) = setup(256, 6, 0.1);
        let ctx = RunCtx::new(5).with_tol(0.0);
        let res = run_gd(&mut cluster, &GdOptions::default(), &ctx).unwrap();
        let last = res.trace.rows.last().unwrap();
        // 5 gradient rounds + 1 row-norm round for the step size
        assert_eq!(last.comm_rounds, 6);
    }

    #[test]
    fn explicit_step_skips_estimation_round() {
        let (mut cluster, _) = setup(256, 6, 0.1);
        let ctx = RunCtx::new(3).with_tol(0.0);
        let res = run_gd(&mut cluster, &GdOptions { step: Some(0.05) }, &ctx).unwrap();
        assert_eq!(res.trace.rows.last().unwrap().comm_rounds, 3);
    }
}
