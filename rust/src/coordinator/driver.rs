//! Experiment driver: config -> dataset -> reference ERM -> cluster ->
//! algorithm -> result. The CLI and all example binaries go through here.

use super::{admm, dane, gd, lbfgs, osa, AlgoResult, RunCtx, SerialCluster};
use crate::config::{AlgoConfig, BackendKind, ExperimentConfig};
use crate::loss::make_objective;
use crate::metrics::Trace;
use crate::runtime::ArtifactRegistry;
use crate::solver::erm_solve;
use crate::Result;
use std::path::Path;
use std::sync::Arc;

/// Everything a finished experiment produced.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub config: ExperimentConfig,
    pub algo: String,
    pub w: Vec<f64>,
    pub trace: Trace,
    pub converged: bool,
    /// Reference optimum the suboptimality axis is measured against.
    pub phi_star: f64,
    /// Rounds to reach config.tol (the fig. 3 metric), if reached.
    pub rounds_to_tol: Option<usize>,
}

/// Run a full experiment from its config.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<RunResult> {
    run_experiment_with_artifacts(cfg, None)
}

/// Like [`run_experiment`], with an explicit artifact dir for the PJRT
/// backend (defaults to `artifacts/`).
pub fn run_experiment_with_artifacts(
    cfg: &ExperimentConfig,
    artifact_dir: Option<&Path>,
) -> Result<RunResult> {
    cfg.validate()?;
    let ds = cfg.dataset.build(cfg.seed)?;
    let obj = make_objective(cfg.loss, cfg.lambda);

    // Reference optimum for the suboptimality axis.
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard())?;

    let mut cluster = SerialCluster::with_net(
        &ds,
        obj,
        cfg.machines,
        cfg.seed.wrapping_add(1),
        cfg.net.build(),
    );
    if cfg.backend == BackendKind::Pjrt {
        let dir = artifact_dir.unwrap_or_else(|| Path::new("artifacts"));
        let registry = Arc::new(ArtifactRegistry::open(dir)?);
        cluster.use_pjrt(registry)?;
    }

    let mut ctx = RunCtx::new(cfg.rounds)
        .with_reference(phi_star)
        .with_tol(cfg.tol);
    if cfg.eval_test {
        if let Some(t) = ds.test_shard() {
            ctx = ctx.with_test_shard(t);
        }
    }

    let result = dispatch(&mut cluster, &cfg.algo, &ctx, cfg.lambda);
    let rounds_to_tol = result.trace.rounds_to_tol(cfg.tol);
    Ok(RunResult {
        config: cfg.clone(),
        algo: result.name,
        w: result.w,
        trace: result.trace,
        converged: result.converged,
        phi_star,
        rounds_to_tol,
    })
}

/// Dispatch an algorithm config onto a cluster.
pub fn dispatch(
    cluster: &mut SerialCluster,
    algo: &AlgoConfig,
    ctx: &RunCtx,
    lambda: f64,
) -> AlgoResult {
    match algo {
        AlgoConfig::Dane { eta, mu_over_lambda } => {
            let opts = dane::DaneOptions {
                eta: *eta,
                mu: mu_over_lambda * lambda,
                ..Default::default()
            };
            dane::run(cluster, &opts, ctx)
        }
        AlgoConfig::Gd { step } => {
            gd::run_gd(cluster, &gd::GdOptions { step: *step }, ctx)
        }
        AlgoConfig::Agd { step } => gd::run_agd(
            cluster,
            &gd::AgdOptions { step: *step, strong_convexity: None },
            ctx,
        ),
        AlgoConfig::Admm { rho } => {
            admm::run(cluster, &admm::AdmmOptions { rho: *rho }, ctx)
        }
        AlgoConfig::Osa { bias_correction_r } => osa::run(
            cluster,
            &osa::OsaOptions { bias_correction_r: *bias_correction_r, seed: 7 },
            ctx,
        ),
        AlgoConfig::Lbfgs { history } => lbfgs::run(
            cluster,
            &lbfgs::LbfgsOptions { history: *history, ..Default::default() },
            ctx,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, LossKind, NetConfig};
    use crate::comm::Topology;

    fn base_cfg(algo: AlgoConfig) -> ExperimentConfig {
        ExperimentConfig {
            name: "driver-test".into(),
            dataset: DatasetConfig::Fig2 { n: 512, d: 8, paper_reg: 0.005 },
            loss: LossKind::Ridge,
            lambda: 0.01,
            algo,
            machines: 4,
            rounds: 30,
            tol: 1e-8,
            seed: 11,
            backend: BackendKind::Native,
            eval_test: false,
            net: NetConfig { alpha: 0.0, beta: 0.0, topology: Topology::Star },
        }
    }

    #[test]
    fn dane_experiment_end_to_end() {
        let cfg = base_cfg(AlgoConfig::Dane { eta: 1.0, mu_over_lambda: 0.0 });
        let res = run_experiment(&cfg).unwrap();
        assert!(res.converged);
        assert!(res.rounds_to_tol.unwrap() <= 10);
        assert_eq!(res.algo, "dane");
    }

    #[test]
    fn every_algorithm_dispatches() {
        for algo in [
            AlgoConfig::Dane { eta: 1.0, mu_over_lambda: 1.0 },
            AlgoConfig::Gd { step: None },
            AlgoConfig::Agd { step: None },
            AlgoConfig::Admm { rho: 0.1 },
            AlgoConfig::Osa { bias_correction_r: None },
            AlgoConfig::Osa { bias_correction_r: Some(0.5) },
            AlgoConfig::Lbfgs { history: 5 },
        ] {
            let mut cfg = base_cfg(algo);
            cfg.rounds = 5;
            cfg.tol = 1e-3;
            let res = run_experiment(&cfg).unwrap();
            assert!(!res.trace.is_empty(), "{}", res.algo);
        }
    }

    #[test]
    fn invalid_config_rejected() {
        let mut cfg = base_cfg(AlgoConfig::Gd { step: None });
        cfg.machines = 0;
        assert!(run_experiment(&cfg).is_err());
    }
}
