//! Experiment driver: config -> dataset -> reference ERM -> cluster ->
//! algorithm -> result. The CLI and all example binaries go through here.
//!
//! The cluster engine is config-selected (`engine: serial | threaded`)
//! and every algorithm runs against `&mut dyn Cluster`, so the whole
//! path from JSON to trace is engine-generic. Failures (a dead worker, a
//! singular local solve) propagate as `Err` all the way to the CLI —
//! nothing on this path panics.

use super::checkpoint::{self, Checkpoint, CkptSpec};
use super::fault::SupervisedCluster;
use super::tcp::TcpCluster;
use super::threaded::ThreadedCluster;
use super::{admm, dane, gd, lbfgs, osa, AlgoResult, Cluster, RunCtx, SerialCluster};
use crate::config::{AlgoConfig, BackendKind, EngineKind, ExperimentConfig, FaultPolicy};
use crate::loss::make_objective;
use crate::metrics::Trace;
use crate::runtime::ArtifactRegistry;
use crate::solver::erm_solve;
use crate::{Error, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Everything a finished experiment produced.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub config: ExperimentConfig,
    pub algo: String,
    pub w: Vec<f64>,
    pub trace: Trace,
    pub converged: bool,
    /// Reference optimum the suboptimality axis is measured against.
    pub phi_star: f64,
    /// Rounds to reach config.tol (the fig. 3 metric), if reached.
    pub rounds_to_tol: Option<usize>,
}

/// Run a full experiment from its config.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<RunResult> {
    run_experiment_full(cfg, None, &RunOpts::default())
}

/// CLI-facing knobs that live outside the experiment config because they
/// do not affect the math of the run: periodic checkpointing and resume.
#[derive(Debug, Clone, Default)]
pub struct RunOpts {
    /// Write a checkpoint to this path periodically.
    pub checkpoint: Option<PathBuf>,
    /// Checkpoint cadence in rounds (0 or 1 = every round).
    pub ckpt_every: usize,
    /// Resume from this checkpoint file. Saving continues to the same
    /// file unless `checkpoint` overrides the destination.
    pub resume: Option<PathBuf>,
}

/// Like [`run_experiment`], with checkpoint/resume options.
pub fn run_experiment_with_opts(cfg: &ExperimentConfig, opts: &RunOpts) -> Result<RunResult> {
    run_experiment_full(cfg, None, opts)
}

/// Build the configured engine over `ds`. The shard seed, the `threads`
/// override and the execution topology are identical across engines, so
/// a threaded or tcp run of the same config — under *any* topology — is
/// trace-identical to a serial one (smoke_cluster_parity and
/// topology_parity pin this through the driver). The network model
/// comes from [`ExperimentConfig::effective_net`], so an explicit
/// `topology` key keeps `modeled_seconds` on the same collective
/// algorithm the transport actually executes.
fn build_cluster(
    cfg: &ExperimentConfig,
    ds: &crate::data::Dataset,
    obj: Arc<dyn crate::loss::Objective>,
    artifact_dir: Option<&Path>,
) -> Result<Box<dyn Cluster>> {
    let shard_seed = cfg.seed.wrapping_add(1);
    // Compression EF streams get their own seed lane, like sharding —
    // the same config compresses identically on either concurrent
    // engine (tests/compress_parity.rs pins it).
    let compress_seed = cfg.seed.wrapping_add(4);
    let net = cfg.effective_net();
    let topology = cfg.exec_topology();
    Ok(match cfg.engine {
        // The serial engine executes inline whatever the topology; the
        // key still drove `net` above, keeping its modeled columns
        // comparable to any concurrent engine's run.
        EngineKind::Serial => {
            let mut c = SerialCluster::with_net(ds, obj, cfg.machines, shard_seed, net);
            c.set_gram_threads(cfg.threads);
            if cfg.backend == BackendKind::Pjrt {
                let dir = artifact_dir.unwrap_or_else(|| Path::new("artifacts"));
                let registry = Arc::new(ArtifactRegistry::open(dir)?);
                c.use_pjrt(registry)?;
            }
            Box::new(c)
        }
        // validate() rejects non-serial + pjrt, so no backend switch here.
        EngineKind::Threaded => {
            let mut c = ThreadedCluster::with_topology(
                ds,
                obj,
                cfg.machines,
                shard_seed,
                net,
                cfg.threads,
                topology,
            );
            if let Some(codec) = cfg.compression.codec() {
                c.set_compression(codec, cfg.compression.error_feedback, compress_seed);
            }
            Box::new(c)
        }
        // Worker processes rebuild the objective from (loss, lambda) in
        // their Init frame; the leader-side copy in `obj` is dropped.
        // Same shard seed, same weights, same reduction order — a tcp
        // run stays trace-bit-identical to a serial one
        // (tests/tcp_cluster.rs pins it through this function).
        // With `data: {by_ref: true}` (validate(): tcp + libsvm only)
        // the Init frames carry the dataset *path* and sharding
        // parameters instead of the rows — O(m) startup bytes — and
        // every worker streams its own shard from local disk. Shard
        // assignment uses the same (n, m, shard_seed), so the trace is
        // still bit-identical to a by-value run.
        EngineKind::Tcp => {
            let by_ref_path = if cfg.data_by_ref {
                match &cfg.dataset {
                    crate::config::DatasetConfig::Libsvm { path, .. } => {
                        Some(path.clone())
                    }
                    _ => None, // unreachable past validate()
                }
            } else {
                None
            };
            let mut c = match (&cfg.workers, by_ref_path) {
                (Some(addrs), None) => TcpCluster::connect(
                    ds,
                    cfg.loss,
                    cfg.lambda,
                    addrs,
                    shard_seed,
                    net,
                    cfg.threads,
                    None,
                    topology,
                )?,
                (Some(addrs), Some(path)) => TcpCluster::connect_by_ref(
                    ds,
                    cfg.loss,
                    cfg.lambda,
                    addrs,
                    shard_seed,
                    net,
                    cfg.threads,
                    None,
                    topology,
                    &path,
                )?,
                (None, None) => TcpCluster::self_hosted(
                    ds,
                    cfg.loss,
                    cfg.lambda,
                    cfg.machines,
                    shard_seed,
                    net,
                    cfg.threads,
                    None,
                    topology,
                )?,
                (None, Some(path)) => TcpCluster::self_hosted_by_ref(
                    ds,
                    cfg.loss,
                    cfg.lambda,
                    cfg.machines,
                    shard_seed,
                    net,
                    cfg.threads,
                    None,
                    topology,
                    &path,
                )?,
            };
            if let Some(codec) = cfg.compression.codec() {
                c.set_compression(codec, cfg.compression.error_feedback, compress_seed);
            }
            Box::new(c)
        }
    })
}

/// Like [`run_experiment`], with an explicit artifact dir for the PJRT
/// backend (defaults to `artifacts/`).
pub fn run_experiment_with_artifacts(
    cfg: &ExperimentConfig,
    artifact_dir: Option<&Path>,
) -> Result<RunResult> {
    run_experiment_full(cfg, artifact_dir, &RunOpts::default())
}

/// The full driver path: config -> cluster -> supervisor -> algorithm.
pub fn run_experiment_full(
    cfg: &ExperimentConfig,
    artifact_dir: Option<&Path>,
    opts: &RunOpts,
) -> Result<RunResult> {
    cfg.validate()?;
    let ds = cfg.dataset.build(cfg.seed)?;
    let obj = make_objective(cfg.loss, cfg.lambda);

    // Reference optimum for the suboptimality axis.
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard())?;

    let mut cluster = build_cluster(cfg, &ds, obj, artifact_dir)?;
    if cfg.fault != FaultPolicy::FailFast {
        cluster.enable_recovery(&ds, cfg.seed.wrapping_add(1), cfg.threads);
    }
    // Every run goes through the supervisor: under fail_fast (the
    // default) it is a transparent passthrough, so fault-free traces
    // stay bit-identical across policies. Backoff jitter draws from the
    // cfg.seed+3 stream (dataset / sharding / OSA take +0 / +1 / +2).
    let mut cluster = SupervisedCluster::new(cluster, cfg.fault, cfg.seed.wrapping_add(3));
    if let Ok(spec) = std::env::var("DANE_CHAOS_KILL") {
        if let Some((call, rank)) = spec.split_once(':') {
            if let (Ok(call), Ok(rank)) = (call.parse(), rank.parse()) {
                cluster = cluster.chaos_kill_at(call, rank);
            }
        }
    }

    let mut ctx = RunCtx::new(cfg.rounds)
        .with_reference(phi_star)
        .with_tol(cfg.tol);
    if cfg.eval_test {
        if let Some(t) = ds.test_shard() {
            ctx = ctx.with_test_shard(t);
        }
    }

    if let Some(dest) = opts.checkpoint.clone().or_else(|| opts.resume.clone()) {
        let hash = checkpoint::config_hash(&cfg.to_json_string());
        let mut spec = CkptSpec::new(dest, opts.ckpt_every.max(1), hash);
        if let Some(rp) = &opts.resume {
            let c = Checkpoint::load(rp)?;
            if c.config_hash != hash {
                return Err(Error::Runtime(format!(
                    "checkpoint {} was written by a different config \
                     (hash {:#018x} != {:#018x}); resume refuses to mix runs",
                    rp.display(),
                    c.config_hash,
                    hash
                )));
            }
            spec.resume = Some(c);
        }
        ctx = ctx.with_checkpoint(Arc::new(spec));
    }

    let result = dispatch(&mut cluster, &cfg.algo, &ctx, cfg.lambda, cfg.seed)?;
    let rounds_to_tol = result.trace.rounds_to_tol(cfg.tol);
    Ok(RunResult {
        config: cfg.clone(),
        algo: result.name,
        w: result.w,
        trace: result.trace,
        converged: result.converged,
        phi_star,
        rounds_to_tol,
    })
}

/// Dispatch an algorithm config onto any cluster engine. `seed` is the
/// experiment seed; per-algorithm randomness (OSA's subsample draw)
/// derives from it so that `cfg.seed` reproduces every run. Algorithm
/// failures come back as `Err` — never a panic. Flattening to
/// `crate::Error` keeps only a progress summary (algo, rounds
/// recorded, cause); callers that need the partial trace itself should
/// call the algorithm's `run` directly and inspect the `AlgoError`.
pub fn dispatch(
    cluster: &mut dyn Cluster,
    algo: &AlgoConfig,
    ctx: &RunCtx,
    lambda: f64,
    seed: u64,
) -> Result<AlgoResult> {
    Ok(match algo {
        AlgoConfig::Dane { eta, mu_over_lambda } => {
            let opts = dane::DaneOptions {
                eta: *eta,
                mu: mu_over_lambda * lambda,
                ..Default::default()
            };
            dane::run(cluster, &opts, ctx)?
        }
        AlgoConfig::Gd { step } => {
            gd::run_gd(cluster, &gd::GdOptions { step: *step }, ctx)?
        }
        AlgoConfig::Agd { step } => gd::run_agd(
            cluster,
            &gd::AgdOptions { step: *step, strong_convexity: None },
            ctx,
        )?,
        AlgoConfig::Admm { rho } => {
            admm::run(cluster, &admm::AdmmOptions { rho: *rho }, ctx)?
        }
        // Seed streams: cfg.seed draws the dataset, cfg.seed+1 the
        // sharding, cfg.seed+2 the OSA subsample — disjoint by offset.
        AlgoConfig::Osa { bias_correction_r } => osa::run(
            cluster,
            &osa::OsaOptions {
                bias_correction_r: *bias_correction_r,
                seed: seed.wrapping_add(2),
            },
            ctx,
        )?,
        AlgoConfig::Lbfgs { history } => lbfgs::run(
            cluster,
            &lbfgs::LbfgsOptions { history: *history, ..Default::default() },
            ctx,
        )?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Topology;
    use crate::config::{DatasetConfig, LossKind, NetConfig};

    fn base_cfg(algo: AlgoConfig) -> ExperimentConfig {
        ExperimentConfig {
            name: "driver-test".into(),
            dataset: DatasetConfig::Fig2 { n: 512, d: 8, paper_reg: 0.005 },
            loss: LossKind::Ridge,
            lambda: 0.01,
            algo,
            machines: 4,
            rounds: 30,
            tol: 1e-8,
            seed: 11,
            backend: BackendKind::Native,
            engine: EngineKind::Serial,
            workers: None,
            threads: None,
            topology: None,
            data_by_ref: false,
            eval_test: false,
            net: NetConfig { alpha: 0.0, beta: 0.0, topology: Topology::Star },
            fault: FaultPolicy::FailFast,
            compression: crate::config::CompressionConfig::default(),
        }
    }

    #[test]
    fn dane_experiment_end_to_end() {
        let cfg = base_cfg(AlgoConfig::Dane { eta: 1.0, mu_over_lambda: 0.0 });
        let res = run_experiment(&cfg).unwrap();
        assert!(res.converged);
        assert!(res.rounds_to_tol.unwrap() <= 10);
        assert_eq!(res.algo, "dane");
    }

    #[test]
    fn every_algorithm_dispatches() {
        for algo in [
            AlgoConfig::Dane { eta: 1.0, mu_over_lambda: 1.0 },
            AlgoConfig::Gd { step: None },
            AlgoConfig::Agd { step: None },
            AlgoConfig::Admm { rho: 0.1 },
            AlgoConfig::Osa { bias_correction_r: None },
            AlgoConfig::Osa { bias_correction_r: Some(0.5) },
            AlgoConfig::Lbfgs { history: 5 },
        ] {
            let mut cfg = base_cfg(algo);
            cfg.rounds = 5;
            cfg.tol = 1e-3;
            let res = run_experiment(&cfg).unwrap();
            assert!(!res.trace.is_empty(), "{}", res.algo);
        }
    }

    #[test]
    fn every_algorithm_dispatches_on_threaded_engine() {
        for algo in [
            AlgoConfig::Dane { eta: 1.0, mu_over_lambda: 0.0 },
            AlgoConfig::Gd { step: None },
            AlgoConfig::Agd { step: None },
            AlgoConfig::Admm { rho: 0.1 },
            AlgoConfig::Osa { bias_correction_r: Some(0.5) },
            AlgoConfig::Lbfgs { history: 5 },
        ] {
            let mut cfg = base_cfg(algo);
            cfg.engine = EngineKind::Threaded;
            cfg.rounds = 5;
            cfg.tol = 1e-3;
            let res = run_experiment(&cfg).unwrap();
            assert!(!res.trace.is_empty(), "{}", res.algo);
        }
    }

    #[test]
    fn every_algorithm_dispatches_on_threaded_tree() {
        use crate::comm::ExecTopology;
        for algo in [
            AlgoConfig::Dane { eta: 1.0, mu_over_lambda: 0.0 },
            AlgoConfig::Gd { step: None },
            AlgoConfig::Agd { step: None },
            AlgoConfig::Admm { rho: 0.1 },
            AlgoConfig::Osa { bias_correction_r: Some(0.5) },
            AlgoConfig::Lbfgs { history: 5 },
        ] {
            let mut cfg = base_cfg(algo);
            cfg.engine = EngineKind::Threaded;
            cfg.topology = Some(ExecTopology::Tree);
            cfg.rounds = 5;
            cfg.tol = 1e-3;
            let res = run_experiment(&cfg).unwrap();
            assert!(!res.trace.is_empty(), "{}", res.algo);
        }
    }

    #[test]
    fn invalid_config_rejected() {
        let mut cfg = base_cfg(AlgoConfig::Gd { step: None });
        cfg.machines = 0;
        assert!(run_experiment(&cfg).is_err());

        let mut cfg = base_cfg(AlgoConfig::Gd { step: None });
        cfg.engine = EngineKind::Threaded;
        cfg.backend = BackendKind::Pjrt;
        assert!(run_experiment(&cfg).is_err(), "threaded + pjrt must be rejected");

        let mut cfg = base_cfg(AlgoConfig::Gd { step: None });
        cfg.threads = Some(0);
        assert!(run_experiment(&cfg).is_err(), "threads: 0 must be rejected");
    }

    #[test]
    fn osa_subsample_follows_config_seed() {
        // The bias-corrected OSA draw must derive from the experiment
        // seed: same seed -> bit-identical result, different seed (same
        // data, same shards) -> a different subsample, hence different w.
        let algo = AlgoConfig::Osa { bias_correction_r: Some(0.5) };
        let cfg = base_cfg(algo.clone());
        let ds = cfg.dataset.build(cfg.seed).unwrap();
        let obj = make_objective(cfg.loss, cfg.lambda);
        let ctx = RunCtx::new(1);

        let mut run_with = |seed: u64| {
            let mut c = SerialCluster::new(&ds, obj.clone(), cfg.machines, 7);
            dispatch(&mut c, &algo, &ctx, cfg.lambda, seed).unwrap().w
        };
        let w_a = run_with(11);
        let w_b = run_with(11);
        assert_eq!(w_a, w_b, "same experiment seed must reproduce OSA exactly");
        let w_c = run_with(12);
        assert!(w_a != w_c, "the OSA subsample draw must follow cfg.seed");
    }

    #[test]
    fn threads_override_plumbs_to_workers() {
        // threads: Some(2) forces the deterministic parallel Gram build;
        // the resulting run must still converge and match the default
        // build to numerical rounding.
        let mut cfg = base_cfg(AlgoConfig::Dane { eta: 1.0, mu_over_lambda: 0.0 });
        let base = run_experiment(&cfg).unwrap();
        cfg.threads = Some(2);
        let forced = run_experiment(&cfg).unwrap();
        assert!(forced.converged);
        // same math, different reduction order: low-order-bit drift in
        // the Gram perturbs the trajectory, not the optimum
        for (a, b) in base.w.iter().zip(&forced.w) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }
}
