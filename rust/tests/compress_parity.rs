//! The acceptance pins of the compression subsystem (ISSUE: compressed
//! round payloads with error feedback, measured as bytes-vs-loss):
//!
//! * **engine parity under compression** — for every codec, a threaded
//!   run and a tcp run (real spawned worker processes, real socket
//!   frames) of the same config produce bit-identical traces: both
//!   engines share one `LeaderCompressor`/`WorkerCompressor` code path
//!   and fold replies in rank order, so the codec cannot introduce an
//!   engine-dependent difference;
//! * **`codec: none` is the uncompressed protocol** — not merely close:
//!   the default config and an explicit `none` are the same run, and on
//!   tcp the `payload_bytes_raw` counterfactual equals `wire_bytes`
//!   exactly (the trust anchor for every compressed comparison);
//! * **error feedback preserves quality** — top-k at k = d/10 with the
//!   residual accumulators lands within 1e-3 relative of the
//!   uncompressed final objective while moving measurably fewer bytes;
//! * **config gates hold** — compression is an engine-level wire
//!   concern, so the serial engine rejects it at `validate()`.

use dane::comm::ExecTopology;
use dane::config::{
    AlgoConfig, BackendKind, CompressionCodec, CompressionConfig, DatasetConfig,
    EngineKind, ExperimentConfig, FaultPolicy, LossKind, NetConfig,
};
use dane::coordinator::driver::run_experiment;
use dane::metrics::Trace;

fn ensure_worker_bin() {
    // Env-free override (see tcp_cluster.rs::ensure_worker_bin).
    dane::coordinator::tcp::set_worker_binary(env!("CARGO_BIN_EXE_dane"));
}

fn cfg(engine: EngineKind, compression: CompressionConfig) -> ExperimentConfig {
    ExperimentConfig {
        name: "compress-parity".into(),
        dataset: DatasetConfig::Fig2 { n: 2048, d: 32, paper_reg: 0.005 },
        loss: LossKind::Ridge,
        lambda: 0.01,
        algo: AlgoConfig::Dane { eta: 1.0, mu_over_lambda: 1.0 },
        machines: 4,
        rounds: 25,
        tol: 1e-12,
        seed: 7,
        backend: BackendKind::Native,
        engine,
        workers: None,
        threads: None,
        topology: Some(ExecTopology::Star),
        data_by_ref: false,
        eval_test: false,
        net: NetConfig::datacenter(),
        fault: FaultPolicy::FailFast,
        compression,
    }
}

fn comp(codec: CompressionCodec, error_feedback: bool) -> CompressionConfig {
    CompressionConfig { codec, error_feedback }
}

/// Every deterministic column — under a shared codec the engines must
/// agree exactly, wallclock and measured wire aside.
fn assert_traces_identical(a: &Trace, b: &Trace, tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}");
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.round, rb.round, "{tag}");
        assert_eq!(ra.objective, rb.objective, "{tag} round {}", ra.round);
        assert_eq!(ra.suboptimality, rb.suboptimality, "{tag} round {}", ra.round);
        assert_eq!(ra.grad_norm, rb.grad_norm, "{tag} round {}", ra.round);
        assert_eq!(ra.comm_rounds, rb.comm_rounds, "{tag} round {}", ra.round);
        assert_eq!(ra.comm_bytes, rb.comm_bytes, "{tag} round {}", ra.round);
    }
}

#[test]
fn threaded_and_tcp_agree_bit_exactly_under_every_codec() {
    ensure_worker_bin();
    for codec in [
        CompressionCodec::F32,
        CompressionCodec::TopK { k: 3 },
        CompressionCodec::Quant { bits: 4 },
    ] {
        let threaded =
            run_experiment(&cfg(EngineKind::Threaded, comp(codec, true))).unwrap();
        let tcp = run_experiment(&cfg(EngineKind::Tcp, comp(codec, true))).unwrap();
        let tag = format!("codec {codec:?}");
        assert_eq!(threaded.w, tcp.w, "{tag}: final iterates must be bit-identical");
        assert_eq!(threaded.phi_star, tcp.phi_star, "{tag}");
        assert_traces_identical(&threaded.trace, &tcp.trace, &tag);

        // in-memory engine: no measured wire, no counterfactual
        assert!(
            threaded
                .trace
                .rows
                .iter()
                .all(|r| r.wire_bytes == 0 && r.payload_bytes_raw == 0),
            "{tag}: threaded engine reported measured bytes"
        );
        // tcp: the counterfactual strictly dominates the measured bytes
        // for every shrinking codec (that is what compression buys)
        let last = tcp.trace.rows.last().unwrap();
        assert!(last.wire_bytes > 0, "{tag}: tcp measured no bytes");
        assert!(
            last.payload_bytes_raw > last.wire_bytes,
            "{tag}: raw {} should exceed wire {}",
            last.payload_bytes_raw,
            last.wire_bytes
        );
    }
}

#[test]
fn codec_none_is_bit_identical_to_the_default_config() {
    ensure_worker_bin();
    // explicit `codec: none` and an absent compression key are the same
    // run — the knob in its default position must not exist on the wire
    let default_run =
        run_experiment(&cfg(EngineKind::Tcp, CompressionConfig::default())).unwrap();
    let none_run =
        run_experiment(&cfg(EngineKind::Tcp, comp(CompressionCodec::None, false)))
            .unwrap();
    assert_eq!(default_run.w, none_run.w, "codec none changed the iterates");
    assert_traces_identical(&default_run.trace, &none_run.trace, "none vs default");

    // trust anchor: uncompressed tcp reports payload_bytes_raw equal to
    // wire_bytes in every row, so compressed ratios compare like with like
    for r in &none_run.trace.rows {
        assert!(r.wire_bytes > 0, "round {}: no measured bytes", r.round);
        assert_eq!(
            r.payload_bytes_raw, r.wire_bytes,
            "round {}: codec none must report raw == wire",
            r.round
        );
    }
}

#[test]
fn topk_with_error_feedback_matches_uncompressed_quality() {
    // The tentpole claim at test scale: top-k keeping ~d/10 coordinates
    // with the error-feedback residual reaches the uncompressed final
    // objective to < 1e-3 relative. Threaded engine keeps it cheap; the
    // parity test above makes the result transfer to tcp verbatim.
    let base =
        run_experiment(&cfg(EngineKind::Threaded, CompressionConfig::default()))
            .unwrap();
    let topk = run_experiment(&cfg(
        EngineKind::Threaded,
        comp(CompressionCodec::TopK { k: 3 }, true),
    ))
    .unwrap();
    let (a, b) = (
        base.trace.rows.last().unwrap().objective,
        topk.trace.rows.last().unwrap().objective,
    );
    let rel = (a - b).abs() / a.abs().max(f64::MIN_POSITIVE);
    assert!(
        rel < 1e-3,
        "top-k+EF objective {b:.9e} drifted {rel:.3e} from uncompressed {a:.9e}"
    );

    // without error feedback the same codec visibly degrades — the
    // accumulators are load-bearing, not decorative
    let no_ef = run_experiment(&cfg(
        EngineKind::Threaded,
        comp(CompressionCodec::TopK { k: 3 }, false),
    ))
    .unwrap();
    let c = no_ef.trace.rows.last().unwrap().objective;
    assert!(c.is_finite(), "no-EF run diverged to non-finite");
    let rel_no_ef = (a - c).abs() / a.abs().max(f64::MIN_POSITIVE);
    assert!(
        rel_no_ef > rel,
        "EF should tighten the objective gap (with {rel:.3e}, without {rel_no_ef:.3e})"
    );
}

#[test]
fn serial_engine_rejects_compression_at_validate() {
    // compression is a wire-level concern; the serial engine has no wire
    let err = run_experiment(&cfg(
        EngineKind::Serial,
        comp(CompressionCodec::F32, true),
    ))
    .unwrap_err();
    let msg = format!("{err}");
    assert!(
        msg.contains("serial") || msg.contains("compression") || msg.contains("codec"),
        "unhelpful validate error: {msg}"
    );
}
