//! The acceptance pin of the topology layer: **bit-exact trace parity
//! across the full engine × topology matrix**, through `run_experiment`
//! with real spawned worker processes on the TCP side.
//!
//! The fixed-order reduction guarantee (rank-order folds from buffered
//! partials at the root, `comm::topology`) means the *numbers* of a run
//! may not depend on how its collectives were executed:
//!
//! * serial ≡ threaded ≡ tcp for the same config, under every
//!   `topology` key — all columns except wallclock and `wire_bytes`;
//! * star ≡ star-seq ≡ tree for the same engine — all columns except
//!   wallclock, `wire_bytes` and `comm_modeled_seconds` (the model
//!   follows the configured topology, which is the point: modeled vs
//!   measured compares like with like).
//!
//! The tree's measured effect shows up where it should: the leader's
//! `wire_bytes` shrink (it writes the broadcast frame to O(log m) links
//! instead of m) and its modeled seconds drop below the star's.

use dane::comm::ExecTopology;
use dane::config::{
    AlgoConfig, BackendKind, DatasetConfig, EngineKind, ExperimentConfig, FaultPolicy,
    LossKind, NetConfig,
};
use dane::coordinator::driver::{run_experiment, RunResult};
use dane::metrics::Trace;

fn ensure_worker_bin() {
    // Env-free override (see tcp_cluster.rs::ensure_worker_bin).
    dane::coordinator::tcp::set_worker_binary(env!("CARGO_BIN_EXE_dane"));
}

fn cfg(
    engine: EngineKind,
    topology: Option<ExecTopology>,
    machines: usize,
) -> ExperimentConfig {
    ExperimentConfig {
        name: "topology-parity".into(),
        dataset: DatasetConfig::Fig2 { n: 1024, d: 16, paper_reg: 0.005 },
        loss: LossKind::Ridge,
        lambda: 0.01,
        algo: AlgoConfig::Dane { eta: 1.0, mu_over_lambda: 1.0 },
        machines,
        rounds: 12,
        tol: 1e-10,
        seed: 7,
        backend: BackendKind::Native,
        engine,
        workers: None,
        threads: None,
        topology,
        data_by_ref: false,
        eval_test: false,
        net: NetConfig::datacenter(),
        fault: FaultPolicy::FailFast,
        compression: dane::config::CompressionConfig::default(),
    }
}

/// All deterministic columns, `comm_modeled_seconds` included — the
/// same-config cross-engine contract.
fn assert_rows_identical_mod_wire(a: &Trace, b: &Trace, tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}");
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.round, rb.round, "{tag}");
        assert_eq!(ra.objective, rb.objective, "{tag} round {}", ra.round);
        assert_eq!(ra.suboptimality, rb.suboptimality, "{tag} round {}", ra.round);
        assert_eq!(ra.grad_norm, rb.grad_norm, "{tag} round {}", ra.round);
        assert_eq!(ra.test_loss, rb.test_loss, "{tag} round {}", ra.round);
        assert_eq!(ra.comm_rounds, rb.comm_rounds, "{tag} round {}", ra.round);
        assert_eq!(ra.comm_bytes, rb.comm_bytes, "{tag} round {}", ra.round);
        assert_eq!(
            ra.comm_modeled_seconds, rb.comm_modeled_seconds,
            "{tag} round {}",
            ra.round
        );
    }
}

/// Deterministic columns minus `comm_modeled_seconds` — the
/// cross-*topology* contract (the model legitimately moves with the
/// configured topology).
fn assert_rows_identical_mod_model(a: &Trace, b: &Trace, tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}");
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.round, rb.round, "{tag}");
        assert_eq!(ra.objective, rb.objective, "{tag} round {}", ra.round);
        assert_eq!(ra.suboptimality, rb.suboptimality, "{tag} round {}", ra.round);
        assert_eq!(ra.grad_norm, rb.grad_norm, "{tag} round {}", ra.round);
        assert_eq!(ra.test_loss, rb.test_loss, "{tag} round {}", ra.round);
        assert_eq!(ra.comm_rounds, rb.comm_rounds, "{tag} round {}", ra.round);
        assert_eq!(ra.comm_bytes, rb.comm_bytes, "{tag} round {}", ra.round);
    }
}

fn assert_results_identical(a: &RunResult, b: &RunResult, tag: &str) {
    assert_eq!(a.phi_star, b.phi_star, "{tag}");
    assert_eq!(a.w, b.w, "{tag}: final iterates must be bit-identical");
    assert_eq!(a.converged, b.converged, "{tag}");
    assert_eq!(a.rounds_to_tol, b.rounds_to_tol, "{tag}");
    assert_rows_identical_mod_wire(&a.trace, &b.trace, tag);
}

#[test]
fn engine_topology_matrix_is_bit_exact_through_run_experiment() {
    ensure_worker_bin();
    for topo in [ExecTopology::StarSeq, ExecTopology::Star, ExecTopology::Tree] {
        // serial baseline under the same topology key: identical modeled
        // columns by construction (effective_net follows the key).
        let baseline = run_experiment(&cfg(EngineKind::Serial, Some(topo), 4)).unwrap();
        assert!(baseline.trace.rows.iter().all(|r| r.wire_bytes == 0));
        for engine in [EngineKind::Threaded, EngineKind::Tcp] {
            let run = run_experiment(&cfg(engine, Some(topo), 4)).unwrap();
            let tag = format!("{}-{}", engine.name(), topo.name());
            assert_results_identical(&baseline, &run, &tag);
            let wire: Vec<u64> = run.trace.rows.iter().map(|r| r.wire_bytes).collect();
            match engine {
                EngineKind::Tcp => {
                    assert!(wire[0] > 0, "{tag}: no measured bytes");
                    assert!(
                        wire.windows(2).all(|w| w[0] <= w[1]),
                        "{tag}: wire_bytes not monotone: {wire:?}"
                    );
                }
                _ => assert!(
                    wire.iter().all(|&b| b == 0),
                    "{tag}: in-memory engine measured bytes"
                ),
            }
        }
    }
}

#[test]
fn cross_topology_traces_agree_on_deterministic_columns() {
    // Same engine, different topology key: everything deterministic
    // matches except the modeled seconds (which *must* move — that is
    // the modeled-vs-measured point of the key).
    let seq = run_experiment(&cfg(EngineKind::Serial, Some(ExecTopology::StarSeq), 4))
        .unwrap();
    let star =
        run_experiment(&cfg(EngineKind::Serial, Some(ExecTopology::Star), 4)).unwrap();
    let tree =
        run_experiment(&cfg(EngineKind::Serial, Some(ExecTopology::Tree), 4)).unwrap();

    // both star strategies model as Star: fully identical
    assert_rows_identical_mod_wire(&seq.trace, &star.trace, "star-seq vs star");
    // tree: identical modulo the model...
    assert_rows_identical_mod_model(&star.trace, &tree.trace, "star vs tree");
    assert_eq!(star.w, tree.w, "iterates must not depend on the topology");
    // ...and the tree model is strictly cheaper at m = 4 under the
    // datacenter alpha-beta (2·log2(4) = 4 steps vs 2·(4-1) = 6).
    let last_star = star.trace.rows.last().unwrap().comm_modeled_seconds;
    let last_tree = tree.trace.rows.last().unwrap().comm_modeled_seconds;
    assert!(
        last_tree < last_star,
        "tree modeled {last_tree} should beat star modeled {last_star}"
    );
}

#[test]
fn tcp_tree_moves_fewer_leader_bytes_than_tcp_star() {
    // The tree's point on a real wire: the leader writes the broadcast
    // frame to O(log m) root links instead of m sockets, so its
    // measured (leader-adjacent) bytes shrink; the gathered reply
    // bundle is the same m frames either way.
    ensure_worker_bin();
    let star =
        run_experiment(&cfg(EngineKind::Tcp, Some(ExecTopology::Star), 4)).unwrap();
    let tree =
        run_experiment(&cfg(EngineKind::Tcp, Some(ExecTopology::Tree), 4)).unwrap();
    assert_eq!(star.w, tree.w, "topologies must agree bit-exactly");
    let (s, t) = (
        star.trace.rows.last().unwrap().wire_bytes,
        tree.trace.rows.last().unwrap().wire_bytes,
    );
    assert!(t > 0, "tree run measured no bytes");
    assert!(t < s, "tree leader bytes {t} should be below star's {s} (m=4, 3 root links)");
}

#[test]
fn incremental_fold_matches_buffered_reduction_for_every_m() {
    // The incremental rank-prefix folds (threaded star's blocking
    // per-rank receive loop, the tree wiring's `tree_round_fold`, tcp's
    // `fold_round`) must be **bitwise** the buffered rank-order
    // reduction the serial engine computes inline — across shard counts
    // on both sides of the binomial tree's power-of-two structure,
    // including the degenerate m = 1 and the lopsided m = 7. The tcp
    // engine's leg of the same contract runs in the matrix test above;
    // this one stays in-memory so the full m sweep is cheap.
    for m in [1usize, 2, 4, 7, 8] {
        for topo in [ExecTopology::Star, ExecTopology::Tree] {
            let serial = run_experiment(&cfg(EngineKind::Serial, Some(topo), m)).unwrap();
            let run = run_experiment(&cfg(EngineKind::Threaded, Some(topo), m)).unwrap();
            let tag = format!("m={m} {}", topo.name());
            assert_results_identical(&serial, &run, &tag);
        }
    }
}

#[test]
fn non_power_of_two_tree_matches_star_through_run_experiment() {
    // m = 7: uneven shards, a lopsided binomial tree (root links
    // {0,2,6,4?}.. whatever the plan says) — parity must not depend on
    // m being a power of two. In-memory engines keep it cheap.
    let star =
        run_experiment(&cfg(EngineKind::Threaded, Some(ExecTopology::Star), 7)).unwrap();
    let tree =
        run_experiment(&cfg(EngineKind::Threaded, Some(ExecTopology::Tree), 7)).unwrap();
    assert_eq!(star.w, tree.w, "m=7: final iterates must be bit-identical");
    assert_rows_identical_mod_model(&star.trace, &tree.trace, "m=7 star vs tree");
}
