//! Build-skeleton smoke test: the two cluster engines are the same
//! machine.
//!
//! `SerialCluster` (inline, the measurement engine) and `ThreadedCluster`
//! (one OS thread per worker behind mpsc channels) implement the same
//! `Cluster` collective surface with the same reduction semantics: shards
//! from the same seed, n_i-weighted gradient averages accumulated in rank
//! order, unweighted DANE iterate averages in rank order (threaded.rs
//! docs). A full DANE run on a fixed seed must therefore produce
//! *identical* traces — bit-equal objectives, suboptimalities, gradient
//! norms, iterates and communication accounting; only wallclock may
//! differ.

use dane::config::{
    AlgoConfig, BackendKind, DatasetConfig, EngineKind, ExperimentConfig, FaultPolicy,
    LossKind, NetConfig,
};
use dane::coordinator::dane as dane_algo;
use dane::coordinator::driver::run_experiment;
use dane::coordinator::threaded::ThreadedCluster;
use dane::coordinator::{AlgoResult, Cluster, RunCtx, SerialCluster};
use dane::data::{synthetic_fig2, Dataset};
use dane::loss::{Objective, Ridge, SmoothHinge};
use dane::metrics::Trace;
use dane::solver::erm_solve;
use std::sync::Arc;

/// Run DANE on both engines from one (dataset, seed) and return both results.
fn run_both(
    ds: &Dataset,
    obj: Arc<dyn Objective>,
    m: usize,
    shard_seed: u64,
    opts: &dane_algo::DaneOptions,
    ctx: &RunCtx,
) -> (AlgoResult, AlgoResult) {
    let mut serial = SerialCluster::new(ds, obj.clone(), m, shard_seed);
    let mut threaded = ThreadedCluster::new(ds, obj, m, shard_seed);
    let r_serial = dane_algo::run(&mut serial, opts, ctx).unwrap();
    let r_threaded = dane_algo::run(&mut threaded, opts, ctx).unwrap();
    (r_serial, r_threaded)
}

fn assert_rows_identical(a: &Trace, b: &Trace) {
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.round, rb.round);
        assert_eq!(ra.objective, rb.objective, "round {}", ra.round);
        assert_eq!(ra.suboptimality, rb.suboptimality, "round {}", ra.round);
        assert_eq!(ra.grad_norm, rb.grad_norm, "round {}", ra.round);
        assert_eq!(ra.test_loss, rb.test_loss, "round {}", ra.round);
        assert_eq!(ra.comm_rounds, rb.comm_rounds, "round {}", ra.round);
        assert_eq!(ra.comm_bytes, rb.comm_bytes, "round {}", ra.round);
        // elapsed_seconds is wallclock and legitimately differs
    }
}

fn assert_traces_identical(a: &AlgoResult, b: &AlgoResult) {
    assert_eq!(a.converged, b.converged);
    assert_eq!(a.w, b.w, "final iterates must be bit-identical");
    assert_rows_identical(&a.trace, &b.trace);
}

#[test]
fn serial_and_threaded_dane_traces_are_identical_ridge() {
    let ds = synthetic_fig2(1024, 12, 0.005, 7);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
    let ctx = RunCtx::new(10).with_reference(phi_star).with_tol(1e-10);
    let (a, b) = run_both(&ds, obj, 4, 3, &dane_algo::DaneOptions::default(), &ctx);
    assert!(a.trace.len() > 2, "run produced {} rows", a.trace.len());
    assert_traces_identical(&a, &b);
}

#[test]
fn serial_and_threaded_dane_traces_are_identical_hinge() {
    // Non-quadratic path (Newton-CG local solves) on uneven shards:
    // 1000 rows over 3 workers exercises the n_i-weighted averaging.
    let ds = dane::data::covtype_like(1000, 16, 11);
    let lam = 1e-2;
    let obj: Arc<dyn Objective> = Arc::new(SmoothHinge::new(lam));
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
    let ctx = RunCtx::new(8).with_reference(phi_star).with_tol(1e-8);
    let opts = dane_algo::DaneOptions { eta: 1.0, mu: 3.0 * lam, ..Default::default() };
    let (a, b) = run_both(&ds, obj, 3, 5, &opts, &ctx);
    assert_traces_identical(&a, &b);
}

#[test]
fn threaded_first_combination_matches_serial() {
    // The Theorem-5 variant goes through a dedicated broadcast path on
    // the threaded engine (only rank 0 computes) — pin it too.
    let ds = synthetic_fig2(512, 8, 0.005, 9);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
    let ctx = RunCtx::new(8).with_reference(phi_star).with_tol(1e-9);
    let opts = dane_algo::DaneOptions {
        combine: dane_algo::Combine::First,
        ..Default::default()
    };
    let (a, b) = run_both(&ds, obj, 4, 1, &opts, &ctx);
    assert_traces_identical(&a, &b);
}

/// Engine parity through the config/driver path: the fig2-style config
/// below run with `engine: threaded` must produce a bit-identical trace
/// to `engine: serial` — the driver seeds shards, constructs the engine
/// and dispatches identically, so the engines are interchangeable from
/// `dane run`'s point of view.
#[test]
fn driver_engine_parity_on_fig2_config() {
    let mut cfg = ExperimentConfig {
        name: "parity".into(),
        dataset: DatasetConfig::Fig2 { n: 1024, d: 16, paper_reg: 0.005 },
        loss: LossKind::Ridge,
        lambda: 0.01,
        algo: AlgoConfig::Dane { eta: 1.0, mu_over_lambda: 1.0 },
        machines: 4,
        rounds: 12,
        tol: 1e-10,
        seed: 7,
        backend: BackendKind::Native,
        engine: EngineKind::Serial,
        workers: None,
        threads: None,
        topology: None,
        data_by_ref: false,
        eval_test: false,
        net: NetConfig::datacenter(),
        fault: FaultPolicy::FailFast,
        compression: dane::config::CompressionConfig::default(),
    };
    let serial = run_experiment(&cfg).unwrap();
    cfg.engine = EngineKind::Threaded;
    let threaded = run_experiment(&cfg).unwrap();

    assert_eq!(serial.phi_star, threaded.phi_star);
    assert_eq!(serial.w, threaded.w, "final iterates must be bit-identical");
    assert_eq!(serial.converged, threaded.converged);
    assert_eq!(serial.rounds_to_tol, threaded.rounds_to_tol);
    assert_rows_identical(&serial.trace, &threaded.trace);
}

#[test]
fn parity_holds_for_eval_and_collective_surface() {
    // Trait-surface check outside a full algorithm run: every counted and
    // uncounted collective must agree between the engines.
    let ds = synthetic_fig2(600, 10, 0.005, 13);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.02));
    let mut s = SerialCluster::new(&ds, obj.clone(), 4, 7);
    let mut t = ThreadedCluster::new(&ds, obj, 4, 7);
    assert_eq!(s.m(), t.m());
    assert_eq!(s.dim(), t.dim());

    let w = vec![0.05; 10];
    let (gs, ls) = s.grad_and_loss(&w).unwrap();
    let (gt, lt) = t.grad_and_loss(&w).unwrap();
    assert_eq!(gs, gt);
    assert_eq!(ls, lt);
    assert_eq!(s.loss_only(&w).unwrap(), t.loss_only(&w).unwrap());
    assert_eq!(s.eval_loss(&w).unwrap(), t.eval_loss(&w).unwrap());
    // avg_row_sq_norm reduces in a different association order on the two
    // engines (global sum vs n_i-weighted per-worker means), so it agrees
    // to rounding, not bit-exactly.
    let (rs, rt) = (s.avg_row_sq_norm().unwrap(), t.avg_row_sq_norm().unwrap());
    assert!((rs - rt).abs() <= 1e-12 * rs.abs().max(1.0), "{rs} vs {rt}");
    assert_eq!(s.comm_stats(), t.comm_stats());
}
