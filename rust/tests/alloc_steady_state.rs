//! Steady-state DANE rounds on `ThreadedCluster` **and on a loopback
//! `TcpCluster` under the parallel-star strategy** perform **zero heap
//! allocations on the leader thread** — the acceptance contract of the
//! zero-allocation round protocol (broadcast `Arc` slots / pooled
//! encode frames rewritten in place, reply buffers recycled through the
//! single-slot rendezvous channel, pooled `RankGather` + incremental
//! rank-prefix folding, in-place gradient/iterate accumulation).
//!
//! Mechanism: a counting global allocator that bumps a thread-local
//! counter on every alloc. Worker threads allocate into their own
//! counters (they are allowed transient allocations; the quadratic path
//! makes none either, but that is not what this binary asserts), so the
//! leader-thread count isolates exactly the protocol path the tentpole
//! optimizes. On the TCP side the same split is what makes the contract
//! tractable: the per-link I/O threads own the sockets, decode replies
//! on *their* threads, and hand the leader already-built values through
//! the rendezvous channel (dropping is free — `dealloc` is uncounted by
//! design, matching "allocation"-free, not "touching the allocator"-
//! free). The `star-seq` strategy decodes inline on the leader thread
//! and is exempt by design (documented in `coordinator::tcp`). Warmup
//! rounds build the one-time state (Cholesky factors, broadcast slots,
//! pooled reply/encode buffers); after that, every `grad_and_loss_into`
//! + `dane_round_into` pair must leave the counter untouched.

use dane::comm::{ExecTopology, NetModel};
use dane::config::LossKind;
use dane::coordinator::tcp::TcpCluster;
use dane::coordinator::threaded::ThreadedCluster;
use dane::coordinator::Cluster;
use dane::data::synthetic_fig2;
use dane::loss::{Objective, Ridge};
use dane::worker::serve;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::net::TcpListener;
use std::sync::Arc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates to System; the thread-local bump never allocates
// (const-initialized Cell) and tolerates TLS teardown via try_with.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn leader_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

#[test]
fn threaded_dane_steady_state_is_allocation_free_on_leader() {
    let d = 32;
    let ds = synthetic_fig2(1024, d, 0.005, 7);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
    let mut cluster = ThreadedCluster::new(&ds, obj, 4, 3);

    let mut w = vec![0.0; d];
    let mut w_next = vec![0.0; d];
    let mut g = vec![0.0; d];

    // Warmup: builds the per-worker Cholesky caches, sizes the broadcast
    // slots and cycles the reply pool once through every command type
    // this loop uses.
    for _ in 0..3 {
        cluster.grad_and_loss_into(&w, &mut g).unwrap();
        cluster.dane_round_into(&w, &g, 1.0, 0.01, &mut w_next).unwrap();
        std::mem::swap(&mut w, &mut w_next);
    }

    let before = leader_allocs();
    for _ in 0..25 {
        let loss = cluster.grad_and_loss_into(&w, &mut g).unwrap();
        std::hint::black_box(loss);
        cluster.dane_round_into(&w, &g, 1.0, 0.01, &mut w_next).unwrap();
        std::mem::swap(&mut w, &mut w_next);
    }
    let after = leader_allocs();

    assert_eq!(
        after - before,
        0,
        "leader thread allocated {} times across 25 steady-state DANE rounds",
        after - before
    );
}

#[test]
fn tcp_dane_steady_state_is_allocation_free_on_leader() {
    // In-process loopback workers: genuine `worker::serve` sessions over
    // real sockets, on threads whose allocations land in their own
    // counters. The leader thread runs only the protocol path under
    // test.
    let m = 4;
    let mut addrs = Vec::with_capacity(m);
    for _ in 0..m {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        addrs.push(listener.local_addr().expect("local addr").to_string());
        std::thread::spawn(move || {
            let _ = serve::serve_listener(listener);
        });
    }

    let d = 32;
    let ds = synthetic_fig2(1024, d, 0.005, 7);
    let mut cluster = TcpCluster::connect(
        &ds,
        LossKind::Ridge,
        0.01,
        &addrs,
        7,
        NetModel::free(),
        None,
        None,
        ExecTopology::Star,
    )
    .expect("tcp cluster over in-process workers");

    let mut w = vec![0.0; d];
    let mut w_next = vec![0.0; d];
    let mut g = vec![0.0; d];

    // Warmup: sizes the pooled encode frame and the rank gather, grows
    // the link I/O threads' read buffers, builds the worker caches.
    for _ in 0..3 {
        cluster.grad_and_loss_into(&w, &mut g).unwrap();
        cluster.dane_round_into(&w, &g, 1.0, 0.01, &mut w_next).unwrap();
        std::mem::swap(&mut w, &mut w_next);
    }

    let before = leader_allocs();
    for _ in 0..25 {
        let loss = cluster.grad_and_loss_into(&w, &mut g).unwrap();
        std::hint::black_box(loss);
        cluster.dane_round_into(&w, &g, 1.0, 0.01, &mut w_next).unwrap();
        std::mem::swap(&mut w, &mut w_next);
    }
    let after = leader_allocs();

    assert_eq!(
        after - before,
        0,
        "tcp leader thread allocated {} times across 25 steady-state DANE rounds",
        after - before
    );
}

#[test]
fn counting_allocator_actually_counts() {
    // guard against the assertion above passing vacuously
    let before = leader_allocs();
    let v: Vec<u64> = std::hint::black_box((0..64).collect());
    std::hint::black_box(&v);
    let after = leader_allocs();
    assert!(after > before, "allocator hook not engaged");
}
