//! Steady-state DANE rounds on `ThreadedCluster` perform **zero heap
//! allocations on the leader thread** — the acceptance contract of the
//! zero-allocation round protocol (broadcast `Arc` slots rewritten in
//! place, reply buffers recycled through the single-slot rendezvous
//! channel, in-place gradient/iterate accumulation).
//!
//! Mechanism: a counting global allocator that bumps a thread-local
//! counter on every alloc. Worker threads allocate into their own
//! counters (they are allowed transient allocations; the quadratic path
//! makes none either, but that is not what this binary asserts), so the
//! leader-thread count isolates exactly the protocol path the tentpole
//! optimizes. Warmup rounds build the one-time state (Cholesky factors,
//! broadcast slots, pooled reply buffers); after that, every
//! `grad_and_loss_into` + `dane_round_into` pair must leave the counter
//! untouched.

use dane::coordinator::threaded::ThreadedCluster;
use dane::coordinator::Cluster;
use dane::data::synthetic_fig2;
use dane::loss::{Objective, Ridge};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates to System; the thread-local bump never allocates
// (const-initialized Cell) and tolerates TLS teardown via try_with.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn leader_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

#[test]
fn threaded_dane_steady_state_is_allocation_free_on_leader() {
    let d = 32;
    let ds = synthetic_fig2(1024, d, 0.005, 7);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
    let mut cluster = ThreadedCluster::new(&ds, obj, 4, 3);

    let mut w = vec![0.0; d];
    let mut w_next = vec![0.0; d];
    let mut g = vec![0.0; d];

    // Warmup: builds the per-worker Cholesky caches, sizes the broadcast
    // slots and cycles the reply pool once through every command type
    // this loop uses.
    for _ in 0..3 {
        cluster.grad_and_loss_into(&w, &mut g).unwrap();
        cluster.dane_round_into(&w, &g, 1.0, 0.01, &mut w_next).unwrap();
        std::mem::swap(&mut w, &mut w_next);
    }

    let before = leader_allocs();
    for _ in 0..25 {
        let loss = cluster.grad_and_loss_into(&w, &mut g).unwrap();
        std::hint::black_box(loss);
        cluster.dane_round_into(&w, &g, 1.0, 0.01, &mut w_next).unwrap();
        std::mem::swap(&mut w, &mut w_next);
    }
    let after = leader_allocs();

    assert_eq!(
        after - before,
        0,
        "leader thread allocated {} times across 25 steady-state DANE rounds",
        after - before
    );
}

#[test]
fn counting_allocator_actually_counts() {
    // guard against the assertion above passing vacuously
    let before = leader_allocs();
    let v: Vec<u64> = std::hint::black_box((0..64).collect());
    std::hint::black_box(&v);
    let after = leader_allocs();
    assert!(after > before, "allocator hook not engaged");
}
