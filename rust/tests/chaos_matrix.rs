//! The chaos matrix: a worker killed mid-run must NOT kill the run when
//! the fault policy allows recovery. Every algorithm × {respawn,
//! degrade} × {threaded, tcp} × {star, tree (interior-node kill)} has to
//! finish `Ok`, with the recovery visible in the trace (`recoveries >=
//! 1`, or `alive_workers < m` under degrade) — and fault-free runs under
//! *any* policy must stay bit-identical to the fail_fast baseline, which
//! is what keeps the supervisor out of the parity contract.
//!
//! Also here: the flaky-link fault (a worker whose listener drops the
//! first k redials before accepting — respawn's backoff loop must ride
//! it out) and checkpoint/resume bit-exactness at the algorithm level.

use dane::comm::{ExecTopology, NetModel};
use dane::config::FaultPolicy;
use dane::config::LossKind;
use dane::coordinator::checkpoint::{Checkpoint, CkptSpec};
use dane::coordinator::dane as dane_algo;
use dane::coordinator::fault::SupervisedCluster;
use dane::coordinator::tcp::TcpCluster;
use dane::coordinator::threaded::ThreadedCluster;
use dane::coordinator::{admm, gd, lbfgs, osa};
use dane::coordinator::{AlgoOutcome, Cluster, RunCtx};
use dane::data::{synthetic_fig2, Dataset};
use dane::loss::{Objective, Ridge};
use dane::metrics::Trace;
use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

const M: usize = 4;
const SHARD_SEED: u64 = 3;
const ALGOS: [&str; 6] = ["dane", "gd", "agd", "admm", "osa", "lbfgs"];

fn ensure_worker_bin() {
    // Env-free override (see tcp_cluster.rs::ensure_worker_bin).
    dane::coordinator::tcp::set_worker_binary(env!("CARGO_BIN_EXE_dane"));
}

fn dataset() -> Dataset {
    synthetic_fig2(256, 6, 0.005, 4)
}

fn threaded_cluster(ds: &Dataset, topology: ExecTopology) -> ThreadedCluster {
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
    ThreadedCluster::with_topology(ds, obj, M, SHARD_SEED, NetModel::free(), None, topology)
}

fn tcp_cluster(ds: &Dataset, topology: ExecTopology) -> TcpCluster {
    ensure_worker_bin();
    TcpCluster::self_hosted(
        ds,
        LossKind::Ridge,
        0.01,
        M,
        SHARD_SEED,
        NetModel::free(),
        None,
        Some(Duration::from_secs(10)),
        topology,
    )
    .expect("self-hosted tcp cluster must come up")
}

fn run_algo(c: &mut dyn Cluster, algo: &str) -> AlgoOutcome {
    match algo {
        "dane" => dane_algo::run(c, &Default::default(), &RunCtx::new(5)),
        "gd" => gd::run_gd(c, &Default::default(), &RunCtx::new(5)),
        "agd" => gd::run_agd(c, &Default::default(), &RunCtx::new(5)),
        "admm" => admm::run(c, &admm::AdmmOptions { rho: 0.1 }, &RunCtx::new(5)),
        "osa" => osa::run(c, &Default::default(), &RunCtx::new(1)),
        "lbfgs" => lbfgs::run(c, &Default::default(), &RunCtx::new(5)),
        other => panic!("unknown algo {other}"),
    }
}

/// Bit-exact row compare, modulo the wallclock column.
fn assert_rows_identical_mod_elapsed(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.len(), b.len(), "[{what}] row count");
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        let r = ra.round;
        assert_eq!(ra.round, rb.round, "[{what}]");
        assert_eq!(ra.objective, rb.objective, "[{what}] round {r}");
        assert_eq!(ra.suboptimality, rb.suboptimality, "[{what}] round {r}");
        assert_eq!(ra.grad_norm, rb.grad_norm, "[{what}] round {r}");
        assert_eq!(ra.test_loss, rb.test_loss, "[{what}] round {r}");
        assert_eq!(ra.comm_rounds, rb.comm_rounds, "[{what}] round {r}");
        assert_eq!(ra.comm_bytes, rb.comm_bytes, "[{what}] round {r}");
        assert_eq!(ra.comm_modeled_seconds, rb.comm_modeled_seconds, "[{what}] round {r}");
        assert_eq!(ra.wire_bytes, rb.wire_bytes, "[{what}] round {r}");
        assert_eq!(ra.startup_bytes, rb.startup_bytes, "[{what}] round {r}");
        assert_eq!(ra.alive_workers, rb.alive_workers, "[{what}] round {r}");
        assert_eq!(ra.recoveries, rb.recoveries, "[{what}] round {r}");
    }
}

/// The policies the matrix survives a kill under. `backoff_ms: 1` keeps
/// the respawn path's sleep real but the test fast.
fn recovery_policies() -> [FaultPolicy; 2] {
    [
        FaultPolicy::Respawn { max_retries: 3, backoff_ms: 1 },
        FaultPolicy::Degrade { min_quorum: 2 },
    ]
}

/// Run `algo` with worker `victim` killed right before the 2nd
/// worker-touching collective, under `policy`; the run must finish and
/// the trace must show the recovery.
fn assert_survives(
    mut inner: Box<dyn Cluster>,
    ds: &Dataset,
    algo: &str,
    policy: FaultPolicy,
    victim: usize,
    what: &str,
) {
    inner.enable_recovery(ds, SHARD_SEED, None);
    let mut sup = SupervisedCluster::new(inner, policy, 9).chaos_kill_at(2, victim);
    let res = run_algo(&mut sup, algo)
        .unwrap_or_else(|e| panic!("[{what}] {algo} under {policy:?} died: {e}"));
    let last = res.trace.rows.last().expect("non-empty trace");
    assert!(
        last.recoveries >= 1 || last.alive_workers < M as u64,
        "[{what}] {algo} under {policy:?}: no recovery visible \
         (recoveries {}, alive {})",
        last.recoveries,
        last.alive_workers
    );
    match policy {
        FaultPolicy::Respawn { .. } => {
            assert_eq!(
                last.alive_workers,
                M as u64,
                "[{what}] {algo}: respawn must restore full strength"
            );
            assert!(last.recoveries >= 1, "[{what}] {algo}");
        }
        FaultPolicy::Degrade { min_quorum } => {
            assert!(
                last.alive_workers >= min_quorum as u64,
                "[{what}] {algo}: quorum violated in trace"
            );
        }
        FaultPolicy::FailFast => unreachable!(),
    }
}

#[test]
fn chaos_matrix_threaded() {
    for algo in ALGOS {
        for policy in recovery_policies() {
            for topology in [ExecTopology::Star, ExecTopology::Tree] {
                // Under the binomial tree (m = 4: leader -> {0, 1, 3},
                // 0 relays for 2) rank 0 is the interior node — killing
                // it exercises the relay re-plan, not just a leaf loss.
                let victim = if topology.is_tree() { 0 } else { 2 };
                let ds = dataset();
                let inner = Box::new(threaded_cluster(&ds, topology));
                let what = format!("threaded-{topology:?}");
                assert_survives(inner, &ds, algo, policy, victim, &what);
            }
        }
    }
}

#[test]
fn chaos_matrix_tcp_star() {
    for algo in ALGOS {
        for policy in recovery_policies() {
            let ds = dataset();
            let inner = Box::new(tcp_cluster(&ds, ExecTopology::Star));
            assert_survives(inner, &ds, algo, policy, 2, "tcp-star");
        }
    }
}

#[test]
fn chaos_matrix_tcp_tree_interior_kill() {
    // SIGKILL of the interior relay (rank 0) on real processes; keep the
    // tcp tree leg to one algorithm per policy — the transport path the
    // matrix exercises is identical across algorithms, and real process
    // spawns dominate the test's wall clock.
    for policy in recovery_policies() {
        for algo in ["dane", "admm"] {
            let ds = dataset();
            let inner = Box::new(tcp_cluster(&ds, ExecTopology::Tree));
            assert_survives(inner, &ds, algo, policy, 0, "tcp-tree");
        }
    }
}

#[test]
fn fault_free_runs_bit_identical_under_every_policy() {
    let policies = [
        FaultPolicy::FailFast,
        FaultPolicy::Respawn { max_retries: 3, backoff_ms: 100 },
        FaultPolicy::Degrade { min_quorum: 2 },
    ];
    for algo in ALGOS {
        let ds = dataset();
        let mut bare = threaded_cluster(&ds, ExecTopology::Star);
        let base = run_algo(&mut bare, algo).unwrap();
        for policy in policies {
            let ds = dataset();
            let mut inner = Box::new(threaded_cluster(&ds, ExecTopology::Star));
            inner.enable_recovery(&ds, SHARD_SEED, None);
            let mut sup = SupervisedCluster::new(inner, policy, 9);
            let res = run_algo(&mut sup, algo).unwrap();
            assert_eq!(res.w, base.w, "{algo} under {policy:?}");
            assert_rows_identical_mod_elapsed(
                &base.trace,
                &res.trace,
                &format!("{algo} under {policy:?}"),
            );
        }
    }
}

// ---------------------------------------------------------------------
// Flaky links: the victim's listener drops the first k redials before
// accepting a session — respawn's backoff loop must ride it out.
// ---------------------------------------------------------------------

/// Spawn `M` in-process loop-serving workers; the `flaky` rank serves
/// its first session normally, then drops the next `drops` accepted
/// connections on the floor (a refused redial, as the leader sees it)
/// before going back to serving. Returns the worker addresses.
fn spawn_loop_workers(flaky: usize, drops: usize) -> Vec<String> {
    let mut addrs = Vec::with_capacity(M);
    for rank in 0..M {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        if rank == flaky {
            std::thread::spawn(move || {
                // session 1 (bring-up) served cleanly
                if let Ok((stream, _)) = listener.accept() {
                    let _ = dane::worker::serve::serve_conn(stream);
                }
                for _ in 0..drops {
                    let _ = listener.accept(); // accepted, dropped
                }
                let _ = dane::worker::serve::serve_loop(listener, false);
            });
        } else {
            std::thread::spawn(move || {
                let _ = dane::worker::serve::serve_loop(listener, false);
            });
        }
    }
    addrs
}

#[test]
fn respawn_rides_out_flaky_redials_to_an_external_worker() {
    let ds = dataset();
    let addrs = spawn_loop_workers(2, 2);
    let inner = TcpCluster::connect(
        &ds,
        LossKind::Ridge,
        0.01,
        &addrs,
        SHARD_SEED,
        NetModel::free(),
        None,
        Some(Duration::from_secs(10)),
        ExecTopology::Star,
    )
    .expect("external tcp cluster must come up");
    // External workers cannot be respawned, only redialed: the first two
    // recovery attempts die on the dropped connections, the third lands.
    let mut sup = SupervisedCluster::new(
        Box::new(inner),
        FaultPolicy::Respawn { max_retries: 5, backoff_ms: 1 },
        9,
    )
    .chaos_kill_at(2, 2);
    let res = run_algo(&mut sup, "dane").expect("flaky redials must be survivable");
    let last = res.trace.rows.last().unwrap();
    assert_eq!(last.alive_workers, M as u64);
    assert!(last.recoveries >= 1, "got {}", last.recoveries);
}

// ---------------------------------------------------------------------
// Checkpoint/resume: a resumed run continues the trace bit-exactly.
// ---------------------------------------------------------------------

/// Run `algo` for `rounds` with a checkpoint every round; then resume
/// from the file with a larger budget and compare against one
/// uninterrupted run of the full budget.
fn assert_resume_bit_exact(algo: &str, short: usize, full: usize) {
    let dir = dane::util::tempdir::TempDir::new("chaos-ckpt").unwrap();
    let path = dir.path().join(format!("{algo}.ckpt"));
    let run_rounds = |c: &mut dyn Cluster, ctx: &RunCtx| match algo {
        "dane" => dane_algo::run(c, &Default::default(), ctx),
        "gd" => gd::run_gd(c, &Default::default(), ctx),
        "agd" => gd::run_agd(c, &Default::default(), ctx),
        "admm" => admm::run(c, &admm::AdmmOptions { rho: 0.1 }, ctx),
        "lbfgs" => lbfgs::run(c, &Default::default(), ctx),
        other => panic!("unknown algo {other}"),
    };

    // leg 1: the "crashed" run — checkpoints every round, stops early
    let ds = dataset();
    let mut c1 = threaded_cluster(&ds, ExecTopology::Star);
    let spec = CkptSpec::new(path.clone(), 1, 7);
    let ctx1 = RunCtx::new(short).with_checkpoint(Arc::new(spec));
    run_rounds(&mut c1, &ctx1).unwrap();

    // leg 2: resume from the file with the full budget
    let mut c2 = threaded_cluster(&ds, ExecTopology::Star);
    let mut spec2 = CkptSpec::new(path.clone(), 1, 7);
    spec2.resume = Some(Checkpoint::load(&path).unwrap());
    let ctx2 = RunCtx::new(full).with_checkpoint(Arc::new(spec2));
    let resumed = run_rounds(&mut c2, &ctx2).unwrap();

    // reference: one uninterrupted run of the full budget
    let mut c3 = threaded_cluster(&ds, ExecTopology::Star);
    let uninterrupted = run_rounds(&mut c3, &RunCtx::new(full)).unwrap();

    assert_eq!(resumed.w, uninterrupted.w, "{algo}: resumed iterate drifted");
    assert_rows_identical_mod_elapsed(
        &uninterrupted.trace,
        &resumed.trace,
        &format!("{algo} resume"),
    );
}

#[test]
fn resume_is_bit_exact_for_every_checkpointing_algorithm() {
    // osa is single-shot and has no checkpoint by design.
    for algo in ["dane", "gd", "agd", "admm", "lbfgs"] {
        assert_resume_bit_exact(algo, 3, 6);
    }
}

#[test]
fn resume_refuses_a_checkpoint_from_another_algorithm() {
    // A dane checkpoint under a gd resume must not restore anything:
    // resume_for filters on the algo name, so the run starts from
    // scratch (the driver-level config hash rejects this earlier).
    let dir = dane::util::tempdir::TempDir::new("chaos-ckpt-mismatch").unwrap();
    let path = dir.path().join("dane.ckpt");
    let ds = dataset();
    let mut c1 = threaded_cluster(&ds, ExecTopology::Star);
    let ctx1 = RunCtx::new(3).with_checkpoint(Arc::new(CkptSpec::new(path.clone(), 1, 7)));
    dane_algo::run(&mut c1, &Default::default(), &ctx1).unwrap();

    let mut spec = CkptSpec::new(path.clone(), 1, 7);
    spec.resume = Some(Checkpoint::load(&path).unwrap());
    let mut c2 = threaded_cluster(&ds, ExecTopology::Star);
    let ctx2 = RunCtx::new(3).with_checkpoint(Arc::new(spec));
    let res = gd::run_gd(&mut c2, &Default::default(), &ctx2).unwrap();
    // a fresh gd run records rounds 0..=3 — nothing was restored
    assert_eq!(res.trace.rows.first().unwrap().round, 0);
}
