//! Property-based invariants (DESIGN.md §7) over the in-tree forall
//! driver: sharding partitions, allreduce = serial mean, CG = Cholesky,
//! CSR = dense, comm accounting, DANE's closed form on random quadratics,
//! and JSON config round-trips.

use dane::comm::{Collective, NetModel};
use dane::config::{
    AlgoConfig, BackendKind, DatasetConfig, EngineKind, ExperimentConfig, FaultPolicy,
    LossKind, NetConfig,
};
use dane::data::sharding::shard_indices;
use dane::data::Shard;
use dane::linalg::cg::{cg_solve, CgScratch};
use dane::linalg::{ops, CholeskyFactor, CsrMatrix, DataMatrix, DenseMatrix};
use dane::loss::{Objective, Ridge, ShardHvp};
use dane::util::prop::{forall, gens};
use dane::util::Rng64;
use std::sync::Arc;

#[test]
fn prop_sharding_is_an_even_partition() {
    forall(
        11,
        200,
        |rng| {
            let (n, m) = gens::shard_instance(rng, 400);
            (n, m, rng.next_u64())
        },
        |&(n, m, seed)| {
            let parts = shard_indices(n, m, seed);
            let mut seen = vec![false; n];
            for p in &parts {
                for &i in p {
                    if seen[i] {
                        return Err(format!("index {i} assigned twice"));
                    }
                    seen[i] = true;
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err("not a partition".into());
            }
            let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            if mx - mn > 1 {
                return Err(format!("uneven sizes {sizes:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_allreduce_mean_equals_serial_reduction() {
    forall(
        13,
        200,
        |rng| gens::vecs_f64(rng, 8, 24, 100.0),
        |vecs| {
            let d = vecs[0].len();
            let mut c = Collective::new(NetModel::free());
            let views: Vec<&[f64]> = vecs.iter().map(|v| v.as_slice()).collect();
            let mut out = vec![0.0; d];
            c.allreduce_mean(&views, &mut out);
            for j in 0..d {
                let serial: f64 =
                    vecs.iter().map(|v| v[j]).sum::<f64>() / vecs.len() as f64;
                if (out[j] - serial).abs() > 1e-12 * serial.abs().max(1.0) {
                    return Err(format!("col {j}: {} vs {serial}", out[j]));
                }
            }
            if c.stats().rounds != 1 {
                return Err("allreduce must count one round".into());
            }
            if c.stats().bytes != (vecs.len() * d * 8) as u64 {
                return Err("byte accounting wrong".into());
            }
            Ok(())
        },
    );
}

fn random_spd(rng: &mut Rng64, d: usize) -> DenseMatrix {
    let mut b = DenseMatrix::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            b.set(i, j, rng.range_f64(-1.0, 1.0));
        }
    }
    b.gram().add_diag(0.3)
}

#[test]
fn prop_cg_equals_cholesky_on_spd_systems() {
    forall(
        17,
        60,
        |rng| {
            let d = 2 + rng.below(20);
            let a = random_spd(rng, d);
            let b: Vec<f64> = (0..d).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            (a, b)
        },
        |(a, b)| {
            let d = b.len();
            let chol = CholeskyFactor::factor(a).map_err(|e| e.to_string())?;
            let x_ref = chol.solve(b);
            let mut x = vec![0.0; d];
            let mut s = CgScratch::new(d);
            cg_solve(a, b, &mut x, 1e-12, 10 * d + 50, &mut s)
                .map_err(|e| e.to_string())?;
            let err = ops::dist2(&x, &x_ref);
            if err > 1e-6 * ops::norm2(&x_ref).max(1.0) {
                return Err(format!("cg vs cholesky distance {err}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_csr_equals_dense_on_all_ops() {
    forall(
        19,
        100,
        |rng| {
            let n = 1 + rng.below(20);
            let d = 1 + rng.below(15);
            let mut m = DenseMatrix::zeros(n, d);
            for i in 0..n {
                for j in 0..d {
                    if rng.bool(0.3) {
                        m.set(i, j, rng.range_f64(-3.0, 3.0));
                    }
                }
            }
            let v: Vec<f64> = (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let u: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            (m, v, u)
        },
        |(m, v, u)| {
            let s = CsrMatrix::from_dense(m, 0.0);
            let (n, d) = (m.rows(), m.cols());
            // Dense and CSR sum the same terms in different association
            // orders (the dense dot is 4-lane unrolled), so agreement is
            // to rounding, not bit-exact.
            let close = |a: &[f64], b: &[f64]| {
                a.iter()
                    .zip(b)
                    .all(|(x, y)| (x - y).abs() <= 1e-12 * x.abs().max(y.abs()).max(1.0))
            };
            let (mut o1, mut o2) = (vec![0.0; n], vec![0.0; n]);
            m.matvec(v, &mut o1);
            s.matvec(v, &mut o2);
            if !close(&o1, &o2) {
                return Err("matvec differs".into());
            }
            let (mut r1, mut r2) = (vec![0.0; d], vec![0.0; d]);
            m.rmatvec(u, &mut r1);
            s.rmatvec(u, &mut r2);
            if !close(&r1, &r2) {
                return Err("rmatvec differs".into());
            }
            let (g1, g2) = (m.gram(), s.gram());
            for i in 0..d {
                for j in 0..d {
                    if (g1.get(i, j) - g2.get(i, j)).abs() > 1e-12 {
                        return Err("gram differs".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_hvp_equals_dense_hessian_product() {
    forall(
        23,
        60,
        |rng| {
            let n = 4 + rng.below(30);
            let d = 1 + rng.below(10);
            let mut x = DenseMatrix::zeros(n, d);
            for i in 0..n {
                for j in 0..d {
                    x.set(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
            let y: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 2.0)).collect();
            let v: Vec<f64> = (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let reg = rng.range_f64(0.0, 1.0);
            (x, y, weights, v, reg)
        },
        |(x, y, weights, v, reg)| {
            let (n, d) = (x.rows(), x.cols());
            let shard = Shard::new(DataMatrix::Dense(x.clone()), y.clone());
            let hvp = ShardHvp::new(&shard, weights, *reg);
            let mut got = vec![0.0; d];
            use dane::linalg::LinearOperator;
            hvp.apply(v, &mut got);

            // dense: (1/n) X^T diag(w) X v + reg v
            let mut t = vec![0.0; n];
            x.matvec(v, &mut t);
            for j in 0..n {
                t[j] *= weights[j] / n as f64;
            }
            let mut expect = vec![0.0; d];
            x.rmatvec(&t, &mut expect);
            ops::axpy(*reg, v, &mut expect);
            for j in 0..d {
                if (got[j] - expect[j]).abs() > 1e-10 {
                    return Err(format!("hvp[{j}]: {} vs {}", got[j], expect[j]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dane_local_solve_satisfies_first_order_conditions() {
    // For random quadratic shards: the returned w_i must satisfy
    // (H_i + mu I)(w_i - w') = -eta * g exactly (Theorem-2 algebra).
    forall(
        29,
        40,
        |rng| {
            let n = 10 + rng.below(40);
            let d = 2 + rng.below(8);
            let mut x = DenseMatrix::zeros(n, d);
            for i in 0..n {
                for j in 0..d {
                    x.set(i, j, rng.range_f64(-1.0, 1.0));
                }
            }
            let y: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let w_prev: Vec<f64> = (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let g: Vec<f64> = (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let lam = rng.range_f64(0.01, 0.5);
            let mu = rng.range_f64(0.0, 0.5);
            let eta = rng.range_f64(0.1, 1.0);
            (x, y, w_prev, g, lam, mu, eta)
        },
        |(x, y, w_prev, g, lam, mu, eta)| {
            let d = x.cols();
            let shard = Shard::new(DataMatrix::Dense(x.clone()), y.clone());
            let obj: Arc<dyn Objective> = Arc::new(Ridge::new(*lam));
            let mut worker = dane::worker::Worker::new(0, shard, obj);
            let w_i = worker
                .dane_local_solve(w_prev, g, *eta, *mu)
                .map_err(|e| e.to_string())?;
            // residual: (H_i + mu I)(w_i - w') + eta g = 0
            let hi = worker.dense_hessian().add_diag(*mu);
            let mut diff = vec![0.0; d];
            ops::sub(&w_i, w_prev, &mut diff);
            let mut resid = vec![0.0; d];
            hi.matvec(&diff, &mut resid);
            ops::axpy(*eta, g, &mut resid);
            let r = ops::norm2(&resid);
            if r > 1e-8 {
                return Err(format!("first-order residual {r}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_config_json_roundtrip() {
    forall(
        31,
        120,
        |rng| {
            let algo = match rng.below(6) {
                0 => AlgoConfig::Dane {
                    eta: rng.range_f64(0.1, 2.0),
                    mu_over_lambda: rng.range_f64(0.0, 5.0),
                },
                1 => AlgoConfig::Gd {
                    step: if rng.bool(0.5) { Some(rng.range_f64(0.001, 1.0)) } else { None },
                },
                2 => AlgoConfig::Agd { step: None },
                3 => AlgoConfig::Admm { rho: rng.range_f64(0.001, 10.0) },
                4 => AlgoConfig::Osa {
                    bias_correction_r: if rng.bool(0.5) { Some(rng.range_f64(0.1, 0.9)) } else { None },
                },
                _ => AlgoConfig::Lbfgs { history: 1 + rng.below(20) },
            };
            ExperimentConfig {
                name: format!("prop-{}", rng.below(1000)),
                dataset: DatasetConfig::Fig2 {
                    n: 100 + rng.below(10_000),
                    d: 1 + rng.below(100),
                    paper_reg: rng.range_f64(0.0001, 0.1),
                },
                loss: LossKind::Ridge,
                lambda: rng.range_f64(0.0, 1.0),
                algo,
                machines: 1 + rng.below(64),
                rounds: 1 + rng.below(500),
                tol: rng.range_f64(1e-12, 1e-3),
                seed: rng.next_u64() >> 12,
                backend: BackendKind::Native,
                engine: if rng.bool(0.5) {
                    EngineKind::Threaded
                } else {
                    EngineKind::Serial
                },
                workers: None,
                threads: if rng.bool(0.5) { Some(1 + rng.below(8)) } else { None },
                topology: match rng.below(4) {
                    0 => Some(dane::comm::ExecTopology::StarSeq),
                    1 => Some(dane::comm::ExecTopology::Star),
                    2 => Some(dane::comm::ExecTopology::Tree),
                    _ => None,
                },
                data_by_ref: false,
                eval_test: rng.bool(0.5),
                net: NetConfig::datacenter(),
                fault: FaultPolicy::FailFast,
                compression: dane::config::CompressionConfig::default(),
            }
        },
        |cfg| {
            let s = cfg.to_json_string();
            let back = ExperimentConfig::from_json_str(&s).map_err(|e| e.to_string())?;
            if &back != cfg {
                return Err(format!("roundtrip mismatch:\n{s}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_parse_never_panics_on_fuzz() {
    forall(
        37,
        500,
        |rng| {
            let len = rng.below(40);
            let chars = b"{}[]\",:0123456789.eE+-truefalsn ul\\";
            (0..len)
                .map(|_| chars[rng.below(chars.len())] as char)
                .collect::<String>()
        },
        |s| {
            // must return Ok or Err, never panic
            let _ = dane::util::Json::parse(s);
            Ok(())
        },
    );
}
