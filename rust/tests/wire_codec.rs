//! Codec contract for `comm::wire`: every `Command`/`Reply` variant
//! round-trips bit-exactly (odd dims, empty vectors, NaN/±inf payloads
//! preserved bit for bit), and malformed input — truncated frames, bad
//! version bytes, unknown tags, oversize length prefixes, hostile
//! element counts, trailing garbage — returns `Err`, never a panic and
//! never an attacker-sized allocation.

use dane::comm::compress::{
    Codec, CodedVec, CompressedCmd, CompressedOp, CompressedReply, ReplySpec,
};
use dane::comm::wire::{
    decode_command, decode_reply, encode_command, encode_reply, read_frame, Command,
    InitPayload, InitRefPayload, PeerChild, PeersPayload, Reply, MAX_FRAME_LEN,
    WIRE_VERSION,
};
use dane::data::Shard;
use dane::linalg::{CsrMatrix, DataMatrix, DenseMatrix};
use dane::util::Rng64;
use std::sync::Arc;

// ---------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------

/// Random vector mixing ordinary values with the IEEE specials the codec
/// must carry through untouched.
fn weird_vec(rng: &mut Rng64, len: usize) -> Vec<f64> {
    (0..len)
        .map(|_| match rng.below(8) {
            0 => f64::NAN,
            1 => f64::INFINITY,
            2 => f64::NEG_INFINITY,
            3 => -0.0,
            4 => f64::MIN_POSITIVE / 2.0, // subnormal
            _ => rng.range_f64(-1e300, 1e300),
        })
        .collect()
}

fn assert_bits_eq(a: &[f64], b: &[f64]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y} differ in bits");
    }
}

/// Bit-level equality for a compressed vector: the codec *math* is lossy
/// but the *frame* must carry the encoder's output exactly (f32 NaNs
/// included, which `PartialEq` would miscompare).
fn assert_coded_bits_eq(a: &CodedVec, b: &CodedVec) {
    match (a, b) {
        (CodedVec::F32 { data: x }, CodedVec::F32 { data: y }) => {
            assert_eq!(x.len(), y.len());
            for (p, q) in x.iter().zip(y) {
                assert_eq!(p.to_bits(), q.to_bits(), "{p} vs {q} differ in bits");
            }
        }
        (
            CodedVec::TopK { dim: d1, idx: i1, val: v1 },
            CodedVec::TopK { dim: d2, idx: i2, val: v2 },
        ) => {
            assert_eq!(d1, d2);
            assert_eq!(i1, i2);
            assert_bits_eq(v1, v2);
        }
        (CodedVec::Quant { .. }, CodedVec::Quant { .. }) => {
            // norms on the wire are finite by construction, so derived
            // equality (dim, norm, bits, packed bytes) is exact here
            assert_eq!(a, b);
        }
        _ => panic!("codec variant changed across the wire"),
    }
}

fn body(buf: &[u8]) -> &[u8] {
    &buf[4..]
}

fn rt_cmd(cmd: &Command) -> Command {
    let mut buf = Vec::new();
    encode_command(cmd, &mut buf).unwrap();
    // the length prefix must describe the body exactly
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    assert_eq!(len, buf.len() - 4);
    decode_command(body(&buf)).expect("well-formed command must decode")
}

fn rt_rep(rep: &Reply) -> Reply {
    let mut buf = Vec::new();
    encode_reply(rep, &mut buf).unwrap();
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    assert_eq!(len, buf.len() - 4);
    decode_reply(body(&buf)).expect("well-formed reply must decode")
}

// ---------------------------------------------------------------------
// command round-trips
// ---------------------------------------------------------------------

#[test]
fn grad_loss_and_loss_roundtrip_all_lengths() {
    let mut rng = Rng64::seed_from_u64(1);
    // empty, length-1, odd, power-of-two-straddling lengths
    for len in [0usize, 1, 3, 7, 17, 63, 64, 65, 255] {
        let w = weird_vec(&mut rng, len);
        match rt_cmd(&Command::GradLoss { w: Arc::new(w.clone()), out: vec![1.0; 4] }) {
            Command::GradLoss { w: w2, out } => {
                assert_bits_eq(&w, &w2);
                assert!(out.is_empty(), "out buffer must not cross the wire");
            }
            _ => panic!("variant changed"),
        }
        match rt_cmd(&Command::Loss { w: Arc::new(w.clone()) }) {
            Command::Loss { w: w2 } => assert_bits_eq(&w, &w2),
            _ => panic!("variant changed"),
        }
    }
}

#[test]
fn dane_solve_roundtrips_with_special_hyperparams() {
    let mut rng = Rng64::seed_from_u64(2);
    for len in [1usize, 5, 33] {
        let w_prev = weird_vec(&mut rng, len);
        let g = weird_vec(&mut rng, len);
        for (eta, mu) in [(1.0, 0.0), (f64::NAN, f64::INFINITY), (-0.0, 1e-300)] {
            let cmd = Command::DaneSolve {
                w_prev: Arc::new(w_prev.clone()),
                g: Arc::new(g.clone()),
                eta,
                mu,
                out: Vec::new(),
            };
            match rt_cmd(&cmd) {
                Command::DaneSolve { w_prev: a, g: b, eta: e, mu: m, out } => {
                    assert_bits_eq(&w_prev, &a);
                    assert_bits_eq(&g, &b);
                    assert_eq!(e.to_bits(), eta.to_bits());
                    assert_eq!(m.to_bits(), mu.to_bits());
                    assert!(out.is_empty());
                }
                _ => panic!("variant changed"),
            }
        }
    }
}

#[test]
fn prox_erm_rowsq_roundtrip() {
    let mut rng = Rng64::seed_from_u64(3);
    let v = weird_vec(&mut rng, 9);
    match rt_cmd(&Command::Prox { v: v.clone(), rho: 0.25 }) {
        Command::Prox { v: v2, rho } => {
            assert_bits_eq(&v, &v2);
            assert_eq!(rho, 0.25);
        }
        _ => panic!("variant changed"),
    }
    for subsample in [None, Some((0.5, u64::MAX)), Some((f64::MIN_POSITIVE, 0))] {
        match rt_cmd(&Command::Erm { subsample }) {
            Command::Erm { subsample: s } => match (subsample, s) {
                (None, None) => {}
                (Some((r1, k1)), Some((r2, k2))) => {
                    assert_eq!(r1.to_bits(), r2.to_bits());
                    assert_eq!(k1, k2);
                }
                _ => panic!("subsample flag flipped"),
            },
            _ => panic!("variant changed"),
        }
    }
    assert!(matches!(rt_cmd(&Command::RowSq), Command::RowSq));
}

#[test]
fn init_roundtrips_dense_and_sparse_shards() {
    let mut rng = Rng64::seed_from_u64(4);
    // dense, odd shape, with padding rows
    let mut x = DenseMatrix::zeros(5, 3);
    for i in 0..5 {
        for j in 0..3 {
            x.set(i, j, rng.normal());
        }
    }
    let dense = Shard::with_padding(DataMatrix::Dense(x), weird_vec(&mut rng, 5), 4);
    // sparse, including an all-zero row and an empty trailing row
    let sparse_x = CsrMatrix::from_triplets(
        4,
        10_000,
        &[(0, 9_999, 1.5), (0, 3, -2.0), (2, 500, f64::NAN)],
    );
    let sparse = Shard::new(DataMatrix::Sparse(sparse_x), vec![1.0, -1.0, 1.0, -1.0]);

    for (shard, gram_threads) in [(dense, None), (sparse, Some(4))] {
        let p = InitPayload {
            worker_id: 7,
            loss_name: "smooth_hinge".into(),
            lambda: 1e-5,
            gram_threads,
            shard: shard.clone(),
        };
        match rt_cmd(&Command::Init(Box::new(p))) {
            Command::Init(q) => {
                assert_eq!(q.worker_id, 7);
                assert_eq!(q.loss_name, "smooth_hinge");
                assert_eq!(q.lambda, 1e-5);
                assert_eq!(q.gram_threads, gram_threads);
                assert_eq!(q.shard.n(), shard.n());
                assert_eq!(q.shard.n_effective(), shard.n_effective());
                assert_eq!(q.shard.d(), shard.d());
                assert_bits_eq(&shard.y, &q.shard.y);
                // matrix content, bit for bit, via the generic row view
                let (da, db) = (shard.x.to_dense(), q.shard.x.to_dense());
                for i in 0..shard.n() {
                    for j in 0..shard.d().min(64) {
                        let (a, b) = (da.get(i, j), db.get(i, j));
                        assert_eq!(a.to_bits(), b.to_bits(), "cell ({i},{j})");
                    }
                }
            }
            _ => panic!("variant changed"),
        }
    }
}

#[test]
fn init_ref_roundtrips_with_hostile_strings_and_specials() {
    // paths with spaces/unicode, NaN lambda: all must survive untouched
    let p = InitRefPayload {
        worker_id: 3,
        loss_name: "smooth_hinge".into(),
        lambda: f64::NAN,
        gram_threads: Some(usize::MAX >> 8),
        path: "/data/ASTRO — копия (1).svm".into(),
        dim: usize::MAX >> 8,
        n: 1 << 40,
        machines: 4,
        shard_seed: u64::MAX,
    };
    match rt_cmd(&Command::InitRef(Box::new(p.clone()))) {
        Command::InitRef(q) => {
            assert_eq!(q.worker_id, p.worker_id);
            assert_eq!(q.loss_name, p.loss_name);
            assert_eq!(q.lambda.to_bits(), p.lambda.to_bits());
            assert_eq!(q.gram_threads, p.gram_threads);
            assert_eq!(q.path, p.path);
            assert_eq!(q.dim, p.dim);
            assert_eq!(q.n, p.n);
            assert_eq!(q.machines, p.machines);
            assert_eq!(q.shard_seed, p.shard_seed);
        }
        _ => panic!("variant changed"),
    }
}

#[test]
fn hostile_init_ref_frames_rejected_not_panicked() {
    let good_payload = InitRefPayload {
        worker_id: 1,
        loss_name: "ridge".into(),
        lambda: 0.01,
        gram_threads: None,
        path: "/tmp/shard.svm".into(),
        dim: 16,
        n: 64,
        machines: 4,
        shard_seed: 9,
    };
    let mut buf = Vec::new();
    encode_command(&Command::InitRef(Box::new(good_payload)), &mut buf).unwrap();
    let good = buf[4..].to_vec();
    assert!(decode_command(&good).is_ok());

    // The trailing four u64 fields are (dim, n, machines, shard_seed).
    // Rewrite them in place to forge parameter sets that would panic
    // `shard_indices` if they ever got past the decoder.
    let forge = |dim: u64, n: u64, machines: u64| {
        let mut f = good.clone();
        let end = f.len();
        f[end - 32..end - 24].copy_from_slice(&dim.to_le_bytes());
        f[end - 24..end - 16].copy_from_slice(&n.to_le_bytes());
        f[end - 16..end - 8].copy_from_slice(&machines.to_le_bytes());
        f
    };
    // machines = 0 (division by zero / empty partition)
    assert!(decode_command(&forge(16, 64, 0)).is_err(), "m=0 accepted");
    // worker_id (1) >= machines (1)
    assert!(decode_command(&forge(16, 64, 1)).is_err(), "rank >= m accepted");
    // fewer rows than machines (shard_indices asserts n >= m)
    assert!(decode_command(&forge(16, 2, 4)).is_err(), "n < m accepted");
    // dim 0 (a subset load cannot infer it)
    assert!(decode_command(&forge(0, 64, 4)).is_err(), "dim=0 accepted");

    // hostile path length: tiny frame claiming a huge string — must be
    // Err without an attacker-sized allocation
    let mut frame = vec![WIRE_VERSION, 0x0b]; // CMD_INIT_REF
    frame.extend_from_slice(&1u64.to_le_bytes()); // worker_id
    frame.extend_from_slice(&(1u64 << 60).to_le_bytes()); // loss_name "len"
    assert!(decode_command(&frame).is_err());

    // every single-byte corruption decodes or errors — never panics
    // (under Miri every decode is interpreted, so stride the sweep)
    for i in (0..good.len()).step_by(if cfg!(miri) { 13 } else { 1 }) {
        for delta in [1u8, 0x80] {
            let mut bad = good.clone();
            bad[i] = bad[i].wrapping_add(delta);
            let _ = decode_command(&bad);
        }
    }
}

#[test]
fn peers_prox_all_and_for_roundtrip() {
    let mut rng = Rng64::seed_from_u64(9);
    let p = PeersPayload {
        children: vec![
            PeerChild { rank: 2, addr: "10.1.2.3:7001".into(), ranks: vec![2, 6] },
            PeerChild { rank: 4, addr: "[::1]:9".into(), ranks: vec![4] },
        ],
        expect_parent: false,
    };
    match rt_cmd(&Command::Peers(Box::new(p.clone()))) {
        Command::Peers(q) => assert_eq!(*q, p),
        _ => panic!("variant changed"),
    }
    // empty children (a tree leaf's Peers) round-trips too
    let leaf = PeersPayload { children: Vec::new(), expect_parent: true };
    match rt_cmd(&Command::Peers(Box::new(leaf.clone()))) {
        Command::Peers(q) => assert_eq!(*q, leaf),
        _ => panic!("variant changed"),
    }

    let targets = vec![weird_vec(&mut rng, 5), weird_vec(&mut rng, 5), vec![]];
    match rt_cmd(&Command::ProxAll { targets: targets.clone(), rho: f64::MIN_POSITIVE })
    {
        Command::ProxAll { targets: t, rho } => {
            assert_eq!(rho, f64::MIN_POSITIVE);
            assert_eq!(t.len(), 3);
            for (a, b) in targets.iter().zip(&t) {
                assert_bits_eq(a, b);
            }
        }
        _ => panic!("variant changed"),
    }

    let inner = Command::DaneSolve {
        w_prev: Arc::new(weird_vec(&mut rng, 3)),
        g: Arc::new(weird_vec(&mut rng, 3)),
        eta: 1.0,
        mu: 0.5,
        out: vec![1.0; 3], // loan must not survive the wire
    };
    match rt_cmd(&Command::For { rank: usize::MAX >> 8, inner: Box::new(inner) }) {
        Command::For { rank, inner } => {
            assert_eq!(rank, usize::MAX >> 8);
            match *inner {
                Command::DaneSolve { ref out, .. } => assert!(out.is_empty()),
                _ => panic!("inner variant changed"),
            }
        }
        _ => panic!("variant changed"),
    }
}

#[test]
fn hostile_peers_and_for_frames_rejected() {
    // peer subtree not rooted at the child rank
    let p = PeersPayload {
        children: vec![PeerChild { rank: 2, addr: "x:1".into(), ranks: vec![6, 2] }],
        expect_parent: false,
    };
    let mut buf = Vec::new();
    encode_command(&Command::Peers(Box::new(p)), &mut buf).unwrap();
    assert!(decode_command(&buf[4..]).is_err(), "mis-rooted subtree accepted");

    // hostile children count: tiny frame claiming 2^50 children
    let mut frame = vec![WIRE_VERSION, 0x08]; // CMD_PEERS
    frame.extend_from_slice(&(1u64 << 50).to_le_bytes());
    frame.extend_from_slice(&[0; 8]);
    assert!(decode_command(&frame).is_err());

    // hostile ProxAll target count
    let mut frame = vec![WIRE_VERSION, 0x09]; // CMD_PROX_ALL
    frame.extend_from_slice(&(1u64 << 50).to_le_bytes());
    assert!(decode_command(&frame).is_err());

    // For wrapping a setup frame is rejected on encode and decode
    let setup = Command::For {
        rank: 0,
        inner: Box::new(Command::Erm { subsample: None }),
    };
    encode_command(&setup, &mut buf).unwrap(); // compute inner is fine
    let mut body = buf[4..].to_vec();
    body[10] = 0x01; // rewrite inner tag to CMD_INIT
    assert!(decode_command(&body).is_err(), "For(Init) accepted");
    body[10] = 0x0a; // rewrite inner tag to CMD_FOR (nesting)
    assert!(decode_command(&body).is_err(), "For(For) accepted");
}

// ---------------------------------------------------------------------
// compressed frames (comm::compress codecs inside typed variants)
// ---------------------------------------------------------------------

#[test]
fn compressed_cmd_roundtrips_every_codec_and_len() {
    let mut rng = Rng64::seed_from_u64(7);
    // empty, length-1, odd, and power-of-two-straddling dims; finite
    // payloads because the decoder rejects non-finite top-k values and
    // quant norms by design (see the hostile test below)
    for len in [0usize, 1, 3, 17, 64, 255] {
        let x: Vec<f64> = (0..len).map(|_| rng.range_f64(-5.0, 5.0)).collect();
        for codec in [
            Codec::F32,
            Codec::TopK { k: (len / 3).max(1) },
            Codec::Quant { bits: 4 },
        ] {
            let v0 = CodedVec::encode(codec, &x, &mut rng);
            let v1 = CodedVec::encode(codec, &x, &mut rng);
            let spec = ReplySpec { codec, error_feedback: true, seed: u64::MAX };
            // GradLoss carries one vector, with adversarial hyperparams
            let cmd = CompressedCmd {
                op: CompressedOp::GradLoss,
                eta: f64::NAN,
                mu: f64::NEG_INFINITY,
                spec,
                vecs: vec![v0.clone()],
            };
            match rt_cmd(&Command::CompressedVec(Arc::new(cmd))) {
                Command::CompressedVec(q) => {
                    assert_eq!(q.op, CompressedOp::GradLoss);
                    assert_eq!(q.eta.to_bits(), f64::NAN.to_bits());
                    assert_eq!(q.mu.to_bits(), f64::NEG_INFINITY.to_bits());
                    assert_eq!(q.spec, spec);
                    assert_eq!(q.vecs.len(), 1);
                    assert_coded_bits_eq(&q.vecs[0], &v0);
                }
                _ => panic!("variant changed"),
            }
            // DaneSolve carries two vectors
            let spec = ReplySpec { codec, error_feedback: false, seed: 0 };
            let cmd = CompressedCmd {
                op: CompressedOp::DaneSolve,
                eta: 1.0,
                mu: f64::MIN_POSITIVE,
                spec,
                vecs: vec![v0.clone(), v1.clone()],
            };
            match rt_cmd(&Command::CompressedVec(Arc::new(cmd))) {
                Command::CompressedVec(q) => {
                    assert_eq!(q.op, CompressedOp::DaneSolve);
                    assert_eq!(q.spec, spec);
                    assert_eq!(q.vecs.len(), 2);
                    assert_coded_bits_eq(&q.vecs[0], &v0);
                    assert_coded_bits_eq(&q.vecs[1], &v1);
                }
                _ => panic!("variant changed"),
            }
        }
    }
}

#[test]
fn compressed_reply_roundtrips_with_and_without_loss() {
    let mut rng = Rng64::seed_from_u64(8);
    // the f32 downcast path must carry IEEE specials bit for bit (at f32
    // width) — NaN payloads, ±inf, -0.0 all survive the frame
    let weird = weird_vec(&mut rng, 33);
    let f32v = CodedVec::encode(Codec::F32, &weird, &mut rng);
    let finite: Vec<f64> = (0..33).map(|_| rng.range_f64(-2.0, 2.0)).collect();
    let topk = CodedVec::encode(Codec::TopK { k: 5 }, &finite, &mut rng);
    let quant = CodedVec::encode(Codec::Quant { bits: 1 }, &finite, &mut rng);
    for (vec, loss) in [
        (f32v, Some(f64::NAN)), // loss is uncompressed instrumentation
        (topk, Some(0.25)),
        (quant, None), // DaneSolve replies carry no loss
    ] {
        let rep = CompressedReply { loss, vec: vec.clone() };
        match rt_rep(&Reply::CompressedVec(Box::new(rep))) {
            Reply::CompressedVec(q) => {
                match (loss, q.loss) {
                    (None, None) => {}
                    (Some(a), Some(b)) => assert_eq!(a.to_bits(), b.to_bits()),
                    _ => panic!("loss marker flipped"),
                }
                assert_coded_bits_eq(&q.vec, &vec);
            }
            _ => panic!("variant changed"),
        }
    }
}

/// Hostile-bytes coverage for `Command::CompressedVec` and
/// `Reply::CompressedVec`: forged counts, out-of-order indices,
/// non-finite values, bad codec specs, and blind corruption must all be
/// `Err`, never a panic or an attacker-sized allocation.
#[test]
fn hostile_compressed_vec_frames_rejected_not_panicked() {
    // header of a CMD_COMPRESSED_VEC body up to (and including) the
    // vector count, parameterized on the codec spec
    let header = |codec_id: u8, param: u32, nvecs: u8| {
        let mut f = vec![WIRE_VERSION, 0x0c, 0x01]; // CMD_COMPRESSED_VEC, GradLoss
        f.extend_from_slice(&1.0f64.to_le_bytes()); // eta
        f.extend_from_slice(&0.0f64.to_le_bytes()); // mu
        f.push(codec_id);
        f.extend_from_slice(&param.to_le_bytes());
        f.push(1); // error_feedback
        f.extend_from_slice(&9u64.to_le_bytes()); // seed
        f.push(nvecs);
        f
    };
    // a well-formed frame decodes (sanity for the forgeries below)
    let mut good = header(2, 2, 1); // top-k, k=2
    good.push(2); // CODEC_TOPK vector
    good.extend_from_slice(&4u64.to_le_bytes()); // dim
    good.extend_from_slice(&2u64.to_le_bytes()); // k
    good.extend_from_slice(&1u32.to_le_bytes());
    good.extend_from_slice(&3u32.to_le_bytes());
    good.extend_from_slice(&0.5f64.to_le_bytes());
    good.extend_from_slice(&(-2.0f64).to_le_bytes());
    assert!(matches!(decode_command(&good), Ok(Command::CompressedVec(_))));

    // bad codec specs in the header
    assert!(decode_command(&header(2, 0, 1)).is_err(), "top-k k=0 accepted");
    assert!(decode_command(&header(1, 7, 1)).is_err(), "f32 with param accepted");
    assert!(decode_command(&header(3, 0, 1)).is_err(), "quant bits=0 accepted");
    assert!(decode_command(&header(3, 9, 1)).is_err(), "quant bits=9 accepted");
    assert!(decode_command(&header(9, 1, 1)).is_err(), "unknown codec accepted");
    // vector count must match the op's arity
    assert!(decode_command(&header(2, 2, 2)).is_err(), "GradLoss with 2 vecs");
    assert!(decode_command(&header(2, 2, 0)).is_err(), "GradLoss with 0 vecs");
    // unknown op / bad error-feedback marker
    let mut bad = good.clone();
    bad[2] = 0x07;
    assert!(decode_command(&bad).is_err(), "unknown op accepted");
    let mut bad = good.clone();
    bad[24] = 2; // error_feedback marker after op + eta + mu + codec spec
    assert!(decode_command(&bad).is_err(), "ef marker 2 accepted");

    // hostile coded-vector payloads (appended to a good header)
    let forge = |vec_bytes: &[u8]| {
        let mut f = header(2, 2, 1);
        f.extend_from_slice(vec_bytes);
        decode_command(&f)
    };
    // top-k dim over the allocation cap
    let mut v = vec![2u8];
    v.extend_from_slice(&(1u64 << 60).to_le_bytes());
    v.extend_from_slice(&1u64.to_le_bytes());
    assert!(forge(&v).is_err(), "huge top-k dim accepted");
    // k > dim (padded so the count-vs-remaining guard is not the reason)
    let mut v = vec![2u8];
    v.extend_from_slice(&4u64.to_le_bytes());
    v.extend_from_slice(&5u64.to_le_bytes());
    v.extend_from_slice(&[0u8; 60]);
    assert!(forge(&v).is_err(), "k > dim accepted");
    // unsorted / duplicate / out-of-range indices and non-finite values
    let topk2 = |i0: u32, i1: u32, x0: f64, x1: f64| {
        let mut v = vec![2u8];
        v.extend_from_slice(&4u64.to_le_bytes());
        v.extend_from_slice(&2u64.to_le_bytes());
        v.extend_from_slice(&i0.to_le_bytes());
        v.extend_from_slice(&i1.to_le_bytes());
        v.extend_from_slice(&x0.to_le_bytes());
        v.extend_from_slice(&x1.to_le_bytes());
        v
    };
    assert!(forge(&topk2(3, 1, 0.5, 0.5)).is_err(), "unsorted idx accepted");
    assert!(forge(&topk2(2, 2, 0.5, 0.5)).is_err(), "duplicate idx accepted");
    assert!(forge(&topk2(1, 7, 0.5, 0.5)).is_err(), "idx >= dim accepted");
    assert!(forge(&topk2(1, 3, f64::NAN, 0.5)).is_err(), "NaN top-k accepted");
    assert!(
        forge(&topk2(1, 3, 0.5, f64::INFINITY)).is_err(),
        "inf top-k accepted"
    );
    // quant: non-finite / negative norm, bad bits byte, dim beyond frame
    let quant = |norm: f64, bits: u8, dim: u64, payload: &[u8]| {
        let mut v = vec![3u8];
        v.extend_from_slice(&dim.to_le_bytes());
        v.extend_from_slice(&norm.to_le_bytes());
        v.push(bits);
        v.extend_from_slice(payload);
        v
    };
    assert!(forge(&quant(f64::NAN, 4, 2, &[0; 2])).is_err(), "NaN norm accepted");
    assert!(forge(&quant(-1.0, 4, 2, &[0; 2])).is_err(), "negative norm accepted");
    assert!(forge(&quant(1.0, 0, 2, &[0; 2])).is_err(), "bits=0 accepted");
    assert!(forge(&quant(1.0, 9, 2, &[0; 2])).is_err(), "bits=9 accepted");
    assert!(
        forge(&quant(1.0, 8, u64::MAX, &[0; 8])).is_err(),
        "quant dim beyond frame accepted"
    );

    // reply side: bad loss marker, then hostile vector after a good one
    let mut frame = vec![WIRE_VERSION, 0x86, 2]; // REP_COMPRESSED_VEC
    assert!(decode_reply(&frame).is_err(), "loss marker 2 accepted");
    let mut rep_good = vec![WIRE_VERSION, 0x86, 1];
    rep_good.extend_from_slice(&0.5f64.to_le_bytes());
    rep_good.push(1); // CODEC_F32
    rep_good.extend_from_slice(&2u64.to_le_bytes());
    rep_good.extend_from_slice(&1.0f32.to_le_bytes());
    rep_good.extend_from_slice(&(-1.0f32).to_le_bytes());
    assert!(matches!(decode_reply(&rep_good), Ok(Reply::CompressedVec(_))));
    frame = rep_good.clone();
    frame.truncate(frame.len() - 4); // f32 count now overruns the body
    assert!(decode_reply(&frame).is_err(), "short f32 payload accepted");

    // every single-byte corruption of both frames decodes or errors —
    // never panics (Miri interprets each decode, so stride the sweep)
    for f in [&good, &rep_good] {
        for i in (0..f.len()).step_by(if cfg!(miri) { 13 } else { 1 }) {
            for delta in [1u8, 0x80] {
                let mut bad = f.clone();
                bad[i] = bad[i].wrapping_add(delta);
                let _ = decode_command(&bad);
                let _ = decode_reply(&bad);
            }
        }
    }
}

#[test]
fn topk_tie_break_and_quant_seed_are_deterministic() {
    // equal magnitudes break toward the lower index, pinned exactly:
    // both engines must produce byte-identical frames for the traces to
    // stay bit-exact across the engine matrix
    let x = [1.0, -1.0, 1.0, -1.0, 0.5, 1.0];
    match CodedVec::encode(Codec::TopK { k: 3 }, &x, &mut Rng64::seed_from_u64(0)) {
        CodedVec::TopK { dim, idx, val } => {
            assert_eq!(dim, 6);
            assert_eq!(idx, vec![0, 1, 2]);
            assert_eq!(val, vec![1.0, -1.0, 1.0]);
        }
        _ => panic!("codec changed"),
    }
    // k >= d keeps everything, indices sorted
    match CodedVec::encode(Codec::TopK { k: 99 }, &x, &mut Rng64::seed_from_u64(0)) {
        CodedVec::TopK { dim, idx, .. } => {
            assert_eq!(dim, 6);
            assert_eq!(idx, vec![0, 1, 2, 3, 4, 5]);
        }
        _ => panic!("codec changed"),
    }
    // stochastic quantization is a pure function of (input, seed): same
    // seed -> byte-identical packed payload, different seed -> different
    let mut rng = Rng64::seed_from_u64(12);
    let y: Vec<f64> = (0..257).map(|_| rng.normal()).collect();
    let a = CodedVec::encode(Codec::Quant { bits: 3 }, &y, &mut Rng64::seed_from_u64(42));
    let b = CodedVec::encode(Codec::Quant { bits: 3 }, &y, &mut Rng64::seed_from_u64(42));
    assert_eq!(a, b, "same seed must quantize identically");
    let c = CodedVec::encode(Codec::Quant { bits: 3 }, &y, &mut Rng64::seed_from_u64(43));
    assert_ne!(a, c, "different seed should dither differently");
    // and the frame carries the packed bits losslessly
    let rep = CompressedReply { loss: None, vec: a.clone() };
    match rt_rep(&Reply::CompressedVec(Box::new(rep))) {
        Reply::CompressedVec(q) => assert_coded_bits_eq(&q.vec, &a),
        _ => panic!("variant changed"),
    }
}

// ---------------------------------------------------------------------
// reply round-trips
// ---------------------------------------------------------------------

#[test]
fn every_reply_variant_roundtrips() {
    let mut rng = Rng64::seed_from_u64(5);
    for len in [0usize, 1, 11, 100] {
        let v = weird_vec(&mut rng, len);
        match rt_rep(&Reply::Vec(v.clone())) {
            Reply::Vec(v2) => assert_bits_eq(&v, &v2),
            _ => panic!("variant changed"),
        }
        match rt_rep(&Reply::VecScalar(v.clone(), f64::NAN)) {
            Reply::VecScalar(v2, s) => {
                assert_bits_eq(&v, &v2);
                assert_eq!(s.to_bits(), f64::NAN.to_bits());
            }
            _ => panic!("variant changed"),
        }
        let sub = weird_vec(&mut rng, len / 2);
        match rt_rep(&Reply::VecPair(v.clone(), Some(sub.clone()))) {
            Reply::VecPair(v2, Some(s2)) => {
                assert_bits_eq(&v, &v2);
                assert_bits_eq(&sub, &s2);
            }
            _ => panic!("variant changed"),
        }
        match rt_rep(&Reply::VecPair(v.clone(), None)) {
            Reply::VecPair(v2, None) => assert_bits_eq(&v, &v2),
            _ => panic!("variant changed"),
        }
    }
    match rt_rep(&Reply::Scalar(-f64::INFINITY)) {
        Reply::Scalar(s) => assert_eq!(s, f64::NEG_INFINITY),
        _ => panic!("variant changed"),
    }
    match rt_rep(&Reply::Err("worker 3: singular Gram — ключ".into())) {
        Reply::Err(m) => assert!(m.contains("singular") && m.contains("ключ")),
        _ => panic!("variant changed"),
    }
}

// ---------------------------------------------------------------------
// malformed input
// ---------------------------------------------------------------------

#[test]
fn every_truncation_of_every_variant_is_an_error() {
    let mut rng = Rng64::seed_from_u64(6);
    let mut frames: Vec<Vec<u8>> = Vec::new();
    let mut buf = Vec::new();
    for cmd in [
        Command::GradLoss { w: Arc::new(weird_vec(&mut rng, 5)), out: Vec::new() },
        Command::Loss { w: Arc::new(vec![]) },
        Command::DaneSolve {
            w_prev: Arc::new(weird_vec(&mut rng, 3)),
            g: Arc::new(weird_vec(&mut rng, 3)),
            eta: 1.0,
            mu: 0.5,
            out: Vec::new(),
        },
        Command::Prox { v: weird_vec(&mut rng, 2), rho: 0.1 },
        Command::Erm { subsample: Some((0.5, 9)) },
        Command::RowSq,
        Command::InitRef(Box::new(InitRefPayload {
            worker_id: 0,
            loss_name: "ridge".into(),
            lambda: 0.5,
            gram_threads: Some(2),
            path: "/tmp/x.svm".into(),
            dim: 3,
            n: 12,
            machines: 2,
            shard_seed: 1,
        })),
        Command::Peers(Box::new(PeersPayload {
            children: vec![PeerChild {
                rank: 2,
                addr: "127.0.0.1:4471".into(),
                ranks: vec![2, 6],
            }],
            expect_parent: true,
        })),
        Command::ProxAll {
            targets: vec![weird_vec(&mut rng, 3), weird_vec(&mut rng, 3)],
            rho: 0.25,
        },
        Command::For {
            rank: 3,
            inner: Box::new(Command::Loss { w: Arc::new(weird_vec(&mut rng, 4)) }),
        },
        Command::CompressedVec(Arc::new(CompressedCmd {
            op: CompressedOp::GradLoss,
            eta: 1.0,
            mu: 0.0,
            spec: ReplySpec { codec: Codec::Quant { bits: 4 }, error_feedback: true, seed: 3 },
            vecs: vec![CodedVec::encode(
                Codec::Quant { bits: 4 },
                &[0.5, -1.0, 0.25],
                &mut rng,
            )],
        })),
        Command::CompressedVec(Arc::new(CompressedCmd {
            op: CompressedOp::DaneSolve,
            eta: 1.0,
            mu: 0.5,
            spec: ReplySpec { codec: Codec::TopK { k: 2 }, error_feedback: false, seed: 0 },
            vecs: vec![
                CodedVec::encode(Codec::TopK { k: 2 }, &[0.5, -1.0, 0.25], &mut rng),
                CodedVec::encode(Codec::TopK { k: 2 }, &[2.0, 0.0, -3.0], &mut rng),
            ],
        })),
    ] {
        encode_command(&cmd, &mut buf).unwrap();
        frames.push(buf[4..].to_vec());
    }
    for rep in [
        Reply::Vec(weird_vec(&mut rng, 4)),
        Reply::Scalar(1.0),
        Reply::VecScalar(weird_vec(&mut rng, 4), 2.0),
        Reply::VecPair(weird_vec(&mut rng, 4), Some(weird_vec(&mut rng, 2))),
        Reply::Err("x".into()),
        Reply::CompressedVec(Box::new(CompressedReply {
            loss: Some(0.25),
            vec: CodedVec::encode(Codec::TopK { k: 2 }, &[0.5, -1.0, 0.25], &mut rng),
        })),
        Reply::CompressedVec(Box::new(CompressedReply {
            loss: None,
            vec: CodedVec::encode(Codec::F32, &weird_vec(&mut rng, 3), &mut rng),
        })),
    ] {
        encode_reply(&rep, &mut buf).unwrap();
        frames.push(buf[4..].to_vec());
    }
    for (k, f) in frames.iter().enumerate() {
        // stride the truncation sweep under Miri (interpreted decodes)
        for cut in (0..f.len()).step_by(if cfg!(miri) { 13 } else { 1 }) {
            // a prefix of a valid frame must never decode (as either kind)
            assert!(
                decode_command(&f[..cut]).is_err(),
                "frame {k} cut {cut} decoded as command"
            );
            assert!(
                decode_reply(&f[..cut]).is_err(),
                "frame {k} cut {cut} decoded as reply"
            );
        }
        // and trailing garbage is rejected too
        let mut long = f.clone();
        long.push(0xab);
        assert!(decode_command(&long).is_err() && decode_reply(&long).is_err());
    }
}

#[test]
fn bad_version_unknown_tag_and_wrong_kind_rejected() {
    let mut buf = Vec::new();
    encode_command(&Command::RowSq, &mut buf).unwrap();
    let good = buf[4..].to_vec();

    let mut bad = good.clone();
    bad[0] = WIRE_VERSION.wrapping_add(1);
    assert!(decode_command(&bad).is_err(), "future version accepted");
    let mut bad = good.clone();
    bad[0] = 0;
    assert!(decode_command(&bad).is_err(), "version 0 accepted");

    let mut bad = good.clone();
    bad[1] = 0x6f; // unknown tag
    assert!(decode_command(&bad).is_err());
    assert!(decode_reply(&bad).is_err());

    // a command frame is not a reply frame and vice versa
    assert!(decode_reply(&good).is_err(), "command tag decoded as reply");
    encode_reply(&Reply::Scalar(0.0), &mut buf).unwrap();
    assert!(decode_command(&buf[4..]).is_err(), "reply tag decoded as command");
}

#[test]
fn hostile_counts_do_not_allocate_or_panic() {
    // A tiny frame claiming a 2^60-element vector: must be Err (and, per
    // the count-vs-remaining-bytes guard, must not try to allocate it).
    let mut frame = vec![WIRE_VERSION, 0x81]; // REP_VEC
    frame.extend_from_slice(&(1u64 << 60).to_le_bytes());
    frame.extend_from_slice(&[0; 16]);
    assert!(decode_reply(&frame).is_err());

    // Same for a string length.
    let mut frame = vec![WIRE_VERSION, 0x85]; // REP_ERR
    frame.extend_from_slice(&(u32::MAX).to_le_bytes());
    assert!(decode_reply(&frame).is_err());

    // Non-UTF-8 error text is an error, not a panic.
    let mut frame = vec![WIRE_VERSION, 0x85];
    frame.extend_from_slice(&2u32.to_le_bytes());
    frame.extend_from_slice(&[0xff, 0xfe]);
    assert!(decode_reply(&frame).is_err());
}

#[test]
fn oversize_length_prefix_rejected_before_reading_body() {
    let mut wire = ((MAX_FRAME_LEN + 1) as u32).to_le_bytes().to_vec();
    wire.extend_from_slice(&[0u8; 64]);
    let mut body = Vec::new();
    assert!(read_frame(&mut wire.as_slice(), &mut body).is_err());

    // mid-frame EOF (prefix promises more than the transport delivers)
    let mut short = 100u32.to_le_bytes().to_vec();
    short.extend_from_slice(&[1u8; 10]);
    assert!(read_frame(&mut short.as_slice(), &mut body).is_err());

    // zero / sub-header lengths are malformed
    let zero = 0u32.to_le_bytes().to_vec();
    assert!(read_frame(&mut zero.as_slice(), &mut body).is_err());
}

#[test]
fn read_frame_roundtrips_what_encode_writes() {
    let mut buf = Vec::new();
    encode_reply(&Reply::VecScalar(vec![1.0, -2.5], 7.0), &mut buf).unwrap();
    let mut body = Vec::new();
    let n = read_frame(&mut buf.as_slice(), &mut body).unwrap().unwrap();
    assert_eq!(n, buf.len(), "read_frame must count prefix + body");
    match decode_reply(&body).unwrap() {
        Reply::VecScalar(v, s) => {
            assert_eq!(v, vec![1.0, -2.5]);
            assert_eq!(s, 7.0);
        }
        _ => panic!("variant changed"),
    }
    // and the stream is now cleanly exhausted
    let mut rest: &[u8] = &[];
    assert_eq!(read_frame(&mut rest, &mut body).unwrap(), None);
}

#[test]
fn malformed_init_shards_rejected_not_panicked() {
    // Build a valid Init frame, then corrupt structural fields.
    let x = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
    let p = InitPayload {
        worker_id: 0,
        loss_name: "ridge".into(),
        lambda: 0.1,
        gram_threads: None,
        shard: Shard::new(DataMatrix::Dense(x), vec![1.0, -1.0]),
    };
    let mut buf = Vec::new();
    encode_command(&Command::Init(Box::new(p)), &mut buf).unwrap();
    let good = buf[4..].to_vec();
    assert!(decode_command(&good).is_ok());

    // every single-byte corruption either decodes to *something* or
    // errors — it must never panic (this sweeps version, tag, dims,
    // counts, n_effective, the lot)
    for i in (0..good.len()).step_by(if cfg!(miri) { 13 } else { 1 }) {
        for delta in [1u8, 0x80] {
            let mut bad = good.clone();
            bad[i] = bad[i].wrapping_add(delta);
            let _ = decode_command(&bad); // must not panic
        }
    }
}
