//! Kernel parity: the tiled/blocked/parallel linalg kernels against
//! straightforward reference implementations, across awkward shapes.
//!
//! The production kernels (tiled Gram, blocked right-looking Cholesky,
//! deterministic parallel Gram) are correctness-critical for every DANE
//! figure, so each is pinned property-style against a textbook triple
//! loop: odd row counts, d = 1, zero rows, padded shards, dimensions off
//! either side of the panel/block sizes (Gram column block 128, Cholesky
//! panel 64). Tolerances are relative 1e-12-grade — the kernels reorder
//! floating-point sums, they do not change the math.

use dane::data::Shard;
use dane::linalg::{ops, CholeskyFactor, CsrMatrix, DataMatrix, DenseMatrix};
use dane::util::Rng64;
use dane::worker::local_solver::QuadCache;

fn random(n: usize, d: usize, seed: u64) -> DenseMatrix {
    let mut rng = Rng64::seed_from_u64(seed);
    let mut m = DenseMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.set(i, j, rng.range_f64(-1.0, 1.0));
        }
    }
    m
}

/// Textbook O(n d^2) Gram: g[a][b] = sum_r X[r][a] * X[r][b].
fn gram_naive(m: &DenseMatrix) -> DenseMatrix {
    let (n, d) = (m.rows(), m.cols());
    let mut g = DenseMatrix::zeros(d, d);
    for a in 0..d {
        for b in 0..d {
            let mut s = 0.0;
            for r in 0..n {
                s += m.get(r, a) * m.get(r, b);
            }
            g.set(a, b, s);
        }
    }
    g
}

/// Textbook unblocked Cholesky returning the lower factor as a matrix.
fn cholesky_naive(a: &DenseMatrix) -> Option<DenseMatrix> {
    let d = a.rows();
    let mut l = DenseMatrix::zeros(d, d);
    for i in 0..d {
        for j in 0..=i {
            let mut s = a.get(i, j);
            for k in 0..j {
                s -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l.set(i, j, s.sqrt());
            } else {
                l.set(i, j, s / l.get(j, j));
            }
        }
    }
    Some(l)
}

/// Naive reference for the canonical 4-lane reduction fold every
/// hot-path reduction kernel uses (`linalg::ops` module docs): lanes
/// `a0..a3` stride the term index by 4, combine as
/// `(a0 + a2) + (a1 + a3)`, and a strictly sequential loop folds the
/// remainder. The production kernels must match this **bit-for-bit** —
/// the fold order is part of the cross-engine parity contract, not an
/// implementation detail.
fn lane_fold_naive(n: usize, term: impl Fn(usize) -> f64) -> f64 {
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for c in 0..chunks {
        a0 += term(4 * c);
        a1 += term(4 * c + 1);
        a2 += term(4 * c + 2);
        a3 += term(4 * c + 3);
    }
    let mut acc = (a0 + a2) + (a1 + a3);
    for k in 4 * chunks..n {
        acc += term(k);
    }
    acc
}

#[test]
fn reduction_kernels_match_canonical_lane_fold_bitwise() {
    // lengths on both sides of the 4-lane stride, including the
    // empty/remainder-only shapes and a bench-sized vector
    for &n in &[0usize, 1, 2, 3, 4, 5, 7, 8, 11, 64, 513] {
        let mut rng = Rng64::seed_from_u64(9000 + n as u64);
        let x: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();

        let want_dot = lane_fold_naive(n, |k| x[k] * y[k]);
        assert_eq!(ops::dot(&x, &y).to_bits(), want_dot.to_bits(), "dot n={n}");

        let want_dist = lane_fold_naive(n, |k| {
            let d = x[k] - y[k];
            d * d
        })
        .sqrt();
        assert_eq!(
            ops::dist2(&x, &y).to_bits(),
            want_dist.to_bits(),
            "dist2 n={n}"
        );

        // one CSR row with n nonzeros scattered over a wider dense
        // vector: row_dot gathers, row_sq_norm squares in place
        let cols = 3 * n + 1;
        let trips: Vec<(usize, usize, f64)> =
            (0..n).map(|k| (0usize, 3 * k, x[k])).collect();
        let m = CsrMatrix::from_triplets(1, cols, &trips);
        let v: Vec<f64> = (0..cols).map(|_| rng.range_f64(-2.0, 2.0)).collect();
        let want_row = lane_fold_naive(n, |k| x[k] * v[3 * k]);
        assert_eq!(
            m.row_dot(0, &v).to_bits(),
            want_row.to_bits(),
            "row_dot n={n}"
        );
        let want_sq = lane_fold_naive(n, |k| x[k] * x[k]);
        assert_eq!(
            m.row_sq_norm(0).to_bits(),
            want_sq.to_bits(),
            "row_sq_norm n={n}"
        );
    }
}

fn assert_close(x: f64, y: f64, scale: f64, what: &str) {
    assert!(
        (x - y).abs() <= 1e-11 * scale.max(1.0),
        "{what}: {x} vs {y}"
    );
}

// The shapes that historically break tiled kernels: empty, single row,
// single column, odd remainders against the 8-row panel, and dimensions
// straddling the 128-wide column block.
const GRAM_SHAPES: &[(usize, usize)] = &[
    (0, 3),
    (1, 1),
    (2, 1),
    (3, 2),
    (5, 7),
    (7, 8),
    (8, 5),
    (9, 16),
    (17, 31),
    (33, 64),
    (40, 127),
    (21, 128),
    (19, 129),
    (64, 130),
];

#[test]
fn tiled_gram_matches_naive_reference() {
    for &(n, d) in GRAM_SHAPES {
        let m = random(n, d, 1000 + (n * 31 + d) as u64);
        let got = m.gram();
        let want = gram_naive(&m);
        for a in 0..d {
            for b in 0..d {
                assert_close(
                    got.get(a, b),
                    want.get(a, b),
                    want.fro_norm(),
                    &format!("gram {n}x{d} [{a},{b}]"),
                );
            }
        }
        // and the 2-row reference kernel still agrees too
        let two = m.gram_2row();
        for a in 0..d {
            for b in 0..d {
                assert_close(
                    two.get(a, b),
                    want.get(a, b),
                    want.fro_norm(),
                    &format!("gram_2row {n}x{d} [{a},{b}]"),
                );
            }
        }
    }
}

#[test]
fn parallel_gram_matches_naive_and_is_bit_reproducible() {
    for &(n, d) in &[(7usize, 3usize), (33, 17), (64, 130), (100, 41)] {
        let m = random(n, d, 2000 + (n + d) as u64);
        let want = gram_naive(&m);
        for t in [1usize, 2, 3, 4, 7] {
            let p = m.par_gram(t);
            for a in 0..d {
                for b in 0..d {
                    assert_close(
                        p.get(a, b),
                        want.get(a, b),
                        want.fro_norm(),
                        &format!("par_gram t={t} {n}x{d} [{a},{b}]"),
                    );
                }
            }
            // determinism: same thread count -> identical bits
            assert_eq!(p.data(), m.par_gram(t).data(), "t={t} {n}x{d}");
        }
        // t=1 degenerates to the serial kernel exactly
        assert_eq!(m.par_gram(1).data(), m.gram().data(), "{n}x{d}");
    }
}

#[test]
fn padded_shard_gram_is_bit_exact_for_any_padding() {
    // QuadCache scales by n_effective and relies on zero padding rows
    // leaving the Gram bit-identical, whatever panel decomposition the
    // padded row count lands on.
    let n = 6;
    let d = 9;
    let m = random(n, d, 77);
    let y: Vec<f64> = (0..n).map(|i| (i as f64) - 2.5).collect();
    let base = Shard::new(DataMatrix::Dense(m.clone()), y.clone());
    let c_base = QuadCache::build(&base).unwrap();
    for pad in [1usize, 2, 3, 5, 8, 10] {
        let mut rows: Vec<Vec<f64>> = (0..n).map(|i| m.row(i).to_vec()).collect();
        let mut py = y.clone();
        for _ in 0..pad {
            rows.push(vec![0.0; d]);
            py.push(0.0);
        }
        let padded = Shard::with_padding(
            DataMatrix::Dense(DenseMatrix::from_rows(&rows)),
            py,
            n,
        );
        let c_pad = QuadCache::build(&padded).unwrap();
        assert_eq!(c_base.gram().data(), c_pad.gram().data(), "pad={pad}");
        assert_eq!(c_base.xty(), c_pad.xty(), "pad={pad}");
    }
}

#[test]
fn blocked_cholesky_matches_naive_reference() {
    // d on both sides of the 64-wide panel, plus boundary straddlers
    for &d in &[1usize, 2, 3, 5, 8, 63, 64, 65, 127, 129] {
        let b = random(d, d, 3000 + d as u64);
        let a = b.gram().add_diag(1.0);
        let f = CholeskyFactor::factor(&a).unwrap();
        let want = cholesky_naive(&a).expect("reference must factor SPD input");
        // L L^T reconstructs A through the production solve path
        let rhs: Vec<f64> = (0..d).map(|i| ((i % 7) as f64) - 3.0).collect();
        let x = f.solve(&rhs);
        let mut ax = vec![0.0; d];
        a.matvec(&x, &mut ax);
        let mut resid = vec![0.0; d];
        ops::sub(&ax, &rhs, &mut resid);
        assert!(
            ops::norm2(&resid) <= 1e-9 * ops::norm2(&rhs).max(1.0),
            "d={d} solve residual {}",
            ops::norm2(&resid)
        );
        // and the naive factor agrees with the blocked one entrywise,
        // via the naive triangular solve
        let mut x_ref = rhs.clone();
        for i in 0..d {
            let mut s = x_ref[i];
            for k in 0..i {
                s -= want.get(i, k) * x_ref[k];
            }
            x_ref[i] = s / want.get(i, i);
        }
        for i in (0..d).rev() {
            let mut s = x_ref[i];
            for k in (i + 1)..d {
                s -= want.get(k, i) * x_ref[k];
            }
            x_ref[i] = s / want.get(i, i);
        }
        for i in 0..d {
            assert_close(x[i], x_ref[i], ops::norm2(&x_ref), &format!("d={d} x[{i}]"));
        }
    }
}

#[test]
fn blocked_and_unblocked_factors_reject_the_same_inputs() {
    // not SPD
    let mut a = DenseMatrix::eye(66);
    a.set(65, 65, -0.5);
    assert!(CholeskyFactor::factor(&a).is_err());
    assert!(CholeskyFactor::factor_unblocked(&a).is_err());
    // not square
    let r = DenseMatrix::zeros(4, 5);
    assert!(CholeskyFactor::factor(&r).is_err());
    assert!(CholeskyFactor::factor_unblocked(&r).is_err());
}

#[test]
fn gram_of_zero_matrix_and_single_column() {
    let z = DenseMatrix::zeros(13, 4);
    assert!(z.gram().data().iter().all(|&v| v == 0.0));
    assert!(z.par_gram(3).data().iter().all(|&v| v == 0.0));
    let col = random(9, 1, 4);
    let g = col.gram();
    let mut want = 0.0;
    for i in 0..9 {
        want += col.get(i, 0) * col.get(i, 0);
    }
    assert_close(g.get(0, 0), want, want.abs(), "single column gram");
}
