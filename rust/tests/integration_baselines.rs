//! Cross-algorithm integration: every baseline reaches the same optimum,
//! the communication accounting matches the paper's per-iteration counts,
//! and the orderings the paper reports (DANE beats ADMM beats gradient
//! methods on rounds; OSA is one round but inexact) hold on a shared
//! problem.

use dane::coordinator::dane as dane_algo;
use dane::coordinator::{admm, gd, lbfgs, osa, RunCtx, SerialCluster};
use dane::data::synthetic_fig2;
use dane::linalg::ops;
use dane::loss::{Objective, Ridge, SmoothHinge};
use dane::solver::erm_solve;
use std::sync::Arc;

struct Fixture {
    ds: dane::data::Dataset,
    obj: Arc<dyn Objective>,
    w_hat: Vec<f64>,
    phi_star: f64,
}

fn ridge_fixture() -> Fixture {
    let lam = 0.02;
    let ds = synthetic_fig2(4096, 20, lam / 2.0, 17);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
    let (w_hat, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
    Fixture { ds, obj, w_hat, phi_star }
}

fn cluster_of(f: &Fixture, m: usize) -> SerialCluster {
    SerialCluster::new(&f.ds, f.obj.clone(), m, 3)
}

#[test]
fn all_multiround_algorithms_reach_the_same_optimum() {
    let f = ridge_fixture();
    let tol = 1e-8;

    let runs: Vec<(&str, Vec<f64>, bool)> = vec![
        {
            let mut c = cluster_of(&f, 4);
            let ctx = RunCtx::new(40).with_reference(f.phi_star).with_tol(tol);
            let r = dane_algo::run(&mut c, &Default::default(), &ctx).unwrap();
            ("dane", r.w, r.converged)
        },
        {
            let mut c = cluster_of(&f, 4);
            let ctx = RunCtx::new(3000).with_reference(f.phi_star).with_tol(tol);
            let r = gd::run_gd(&mut c, &Default::default(), &ctx).unwrap();
            ("gd", r.w, r.converged)
        },
        {
            let mut c = cluster_of(&f, 4);
            let ctx = RunCtx::new(1000).with_reference(f.phi_star).with_tol(tol);
            let r = gd::run_agd(&mut c, &Default::default(), &ctx).unwrap();
            ("agd", r.w, r.converged)
        },
        {
            let mut c = cluster_of(&f, 4);
            let ctx = RunCtx::new(500).with_reference(f.phi_star).with_tol(tol);
            let r = admm::run(&mut c, &admm::AdmmOptions { rho: 0.1 }, &ctx).unwrap();
            ("admm", r.w, r.converged)
        },
        {
            let mut c = cluster_of(&f, 4);
            let ctx = RunCtx::new(200).with_reference(f.phi_star).with_tol(tol);
            let r = lbfgs::run(&mut c, &Default::default(), &ctx).unwrap();
            ("lbfgs", r.w, r.converged)
        },
    ];
    for (name, w, converged) in &runs {
        assert!(converged, "{name} failed to converge");
        let err = ops::dist2(w, &f.w_hat);
        assert!(err < 1e-2, "{name}: distance to w_hat {err}");
    }
}

#[test]
fn round_ordering_matches_paper() {
    // iterations-to-tol: DANE < L-BFGS/AGD < GD on an ill-conditioned
    // quadratic with plenty of data per machine.
    let f = ridge_fixture();
    let tol = 1e-7;
    let r2t = |trace: &dane::metrics::Trace| {
        trace
            .rows
            .iter()
            .find(|r| r.suboptimality.map(|s| s < tol).unwrap_or(false))
            .map(|r| r.comm_rounds)
            .unwrap_or(u64::MAX)
    };

    let mut c = cluster_of(&f, 4);
    let ctx = RunCtx::new(40).with_reference(f.phi_star).with_tol(tol);
    let dane_rounds = r2t(&dane_algo::run(&mut c, &Default::default(), &ctx).unwrap().trace);

    let mut c = cluster_of(&f, 4);
    let ctx = RunCtx::new(3000).with_reference(f.phi_star).with_tol(tol);
    let gd_rounds = r2t(&gd::run_gd(&mut c, &Default::default(), &ctx).unwrap().trace);

    let mut c = cluster_of(&f, 4);
    let ctx = RunCtx::new(1000).with_reference(f.phi_star).with_tol(tol);
    let agd_rounds = r2t(&gd::run_agd(&mut c, &Default::default(), &ctx).unwrap().trace);

    assert!(
        dane_rounds < agd_rounds && agd_rounds < gd_rounds,
        "dane {dane_rounds} agd {agd_rounds} gd {gd_rounds}"
    );
}

#[test]
fn osa_single_round_but_inexact() {
    let f = ridge_fixture();
    let m = 16;
    let mut c = cluster_of(&f, m);
    let ctx = RunCtx::new(1).with_reference(f.phi_star);
    let r = osa::run(&mut c, &osa::OsaOptions::default(), &ctx).unwrap();
    let last = r.trace.rows.last().unwrap();
    assert_eq!(last.comm_rounds, 1);
    let s = r.trace.last_suboptimality().unwrap();
    assert!(s > 1e-9, "osa should not be exact: {s}");
    // but far better than the zero vector
    assert!(s < r.trace.rows[0].suboptimality.unwrap() / 10.0);
}

#[test]
fn admm_insensitive_to_data_size_dane_not() {
    // The fig. 2 punchline at integration scale: growing N sharply
    // improves DANE's per-iteration contraction factor (Theorem 3);
    // ADMM's stays in the same ballpark.
    let lam = 0.01;
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
    let mean_rate = |trace: &dane::metrics::Trace| {
        let f = trace.contraction_factors();
        let k = f.len().min(6).max(1);
        f.iter().take(k).sum::<f64>() / k as f64
    };
    let mut dane_rates = Vec::new();
    let mut admm_rates = Vec::new();
    for &n in &[1024usize, 16384] {
        let ds = synthetic_fig2(n, 16, lam / 2.0, 29);
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut c = SerialCluster::new(&ds, obj.clone(), 8, 3);
        let ctx = RunCtx::new(15).with_reference(phi_star).with_tol(1e-14);
        dane_rates.push(mean_rate(
            &dane_algo::run(&mut c, &Default::default(), &ctx).unwrap().trace,
        ));
        let mut c = SerialCluster::new(&ds, obj.clone(), 8, 3);
        let ctx = RunCtx::new(40).with_reference(phi_star).with_tol(1e-14);
        admm_rates.push(mean_rate(
            &admm::run(&mut c, &admm::AdmmOptions { rho: 0.1 }, &ctx).unwrap().trace,
        ));
    }
    // DANE's contraction factor improves by a large multiple...
    assert!(
        dane_rates[1] < 0.4 * dane_rates[0],
        "dane rates {dane_rates:?}"
    );
    // ...much more than ADMM's does.
    let dane_gain = dane_rates[0] / dane_rates[1];
    let admm_gain = admm_rates[0] / admm_rates[1].max(1e-12);
    assert!(
        dane_gain > 2.0 * admm_gain,
        "dane gain {dane_gain:.2} vs admm gain {admm_gain:.2} (rates {dane_rates:?} {admm_rates:?})"
    );
}

#[test]
fn hinge_baselines_agree() {
    let lam = 1e-2;
    let ds = dane::data::covtype_like(4096, 128, 31);
    let obj: Arc<dyn Objective> = Arc::new(SmoothHinge::new(lam));
    let (w_hat, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();

    let mut c = SerialCluster::new(&ds, obj.clone(), 4, 3);
    let ctx = RunCtx::new(40).with_reference(phi_star).with_tol(1e-8);
    let opts = dane_algo::DaneOptions { eta: 1.0, mu: 3.0 * lam, ..Default::default() };
    let r_dane = dane_algo::run(&mut c, &opts, &ctx).unwrap();

    let mut c = SerialCluster::new(&ds, obj.clone(), 4, 3);
    let ctx = RunCtx::new(400).with_reference(phi_star).with_tol(1e-8);
    let r_admm = admm::run(&mut c, &admm::AdmmOptions { rho: 0.1 }, &ctx).unwrap();

    assert!(r_dane.converged && r_admm.converged);
    assert!(ops::dist2(&r_dane.w, &w_hat) < 1e-3);
    assert!(ops::dist2(&r_admm.w, &w_hat) < 1e-3);
}
