//! The error-propagation contract, end to end: a worker dying mid-run
//! must reach the caller of every algorithm as `Err` — with the trace
//! recorded up to the failure intact — and never as a panic, on both
//! cluster engines.
//!
//! Faults are injected two ways:
//! * `FaultInjectCluster` decorates either engine and kills a worker at
//!   a chosen collective call — the full algorithm matrix runs on it;
//! * a genuinely singular local problem (zero feature column, lambda =
//!   mu = 0) makes a real worker's Cholesky fail on both engines.

use dane::coordinator::dane as dane_algo;
use dane::coordinator::fault::FaultInjectCluster;
use dane::coordinator::threaded::ThreadedCluster;
use dane::coordinator::{admm, gd, lbfgs, osa};
use dane::coordinator::{AlgoError, Cluster, RunCtx, SerialCluster};
use dane::data::{synthetic_fig2, Dataset};
use dane::linalg::{DataMatrix, DenseMatrix};
use dane::loss::{Objective, Ridge};
use dane::util::Rng64;
use std::sync::Arc;

const ENGINES: [&str; 2] = ["serial", "threaded"];

fn bare_cluster(engine: &str) -> Box<dyn Cluster> {
    let ds = synthetic_fig2(256, 6, 0.005, 4);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
    match engine {
        "serial" => Box::new(SerialCluster::new(&ds, obj, 4, 3)),
        _ => Box::new(ThreadedCluster::new(&ds, obj, 4, 3)),
    }
}

/// Wrap an engine with a fault on worker 2 at collective call `fail_at`.
fn faulty_cluster(engine: &str, fail_at: usize) -> FaultInjectCluster {
    FaultInjectCluster::new(bare_cluster(engine), 2, fail_at)
}

/// The shared postcondition: an injected fault surfaces as AlgoError
/// with at least `min_rows` trace rows recorded before the failure.
fn assert_fault_surfaced(err: Box<AlgoError>, algo: &str, engine: &str, min_rows: usize) {
    assert_eq!(err.algo, algo);
    assert!(
        err.trace.len() >= min_rows,
        "[{engine}] {algo}: expected >= {min_rows} trace rows before the fault, got {}",
        err.trace.len()
    );
    assert!(
        err.error.to_string().contains("injected fault"),
        "[{engine}] {algo}: unexpected cause {}",
        err.error
    );
    let display = err.to_string();
    assert!(
        display.contains("failed after") && display.contains(algo),
        "[{engine}] {algo}: display {display}"
    );
    // the partial iterate has the problem dimension
    assert_eq!(err.w.len(), 6);
}

#[test]
fn dane_surfaces_fault_with_partial_trace() {
    for engine in ENGINES {
        // calls: grad(1) row0, dane_round(2), grad(3) row1, dane_round(4) X
        let mut c = faulty_cluster(engine, 4);
        let err = dane_algo::run(&mut c, &dane_algo::DaneOptions::default(), &RunCtx::new(10))
            .expect_err("fault must surface");
        assert_fault_surfaced(err, "dane", engine, 2);
    }
}

#[test]
fn dane_first_combine_surfaces_fault() {
    for engine in ENGINES {
        let mut c = faulty_cluster(engine, 2);
        let opts = dane_algo::DaneOptions {
            combine: dane_algo::Combine::First,
            ..Default::default()
        };
        let err = dane_algo::run(&mut c, &opts, &RunCtx::new(10))
            .expect_err("fault must surface");
        assert_fault_surfaced(err, "dane", engine, 1);
    }
}

#[test]
fn gd_surfaces_fault_with_partial_trace() {
    for engine in ENGINES {
        // calls: row_sq(1), grad(2) row0, grad(3) row1, grad(4) X
        let mut c = faulty_cluster(engine, 4);
        let err = gd::run_gd(&mut c, &gd::GdOptions::default(), &RunCtx::new(10))
            .expect_err("fault must surface");
        assert_fault_surfaced(err, "gd", engine, 2);
    }
}

#[test]
fn gd_step_estimation_round_fault_yields_empty_trace() {
    // Dying before the very first counted round still returns cleanly:
    // Err with an empty trace, not a panic.
    for engine in ENGINES {
        let mut c = faulty_cluster(engine, 1);
        let err = gd::run_gd(&mut c, &gd::GdOptions::default(), &RunCtx::new(10))
            .expect_err("fault must surface");
        assert_eq!(err.trace.len(), 0);
        assert!(err.error.to_string().contains("injected fault"));
    }
}

#[test]
fn agd_surfaces_fault_with_partial_trace() {
    for engine in ENGINES {
        let mut c = faulty_cluster(engine, 4);
        let err = gd::run_agd(&mut c, &gd::AgdOptions::default(), &RunCtx::new(10))
            .expect_err("fault must surface");
        assert_fault_surfaced(err, "agd", engine, 1);
    }
}

#[test]
fn admm_surfaces_fault_with_partial_trace() {
    for engine in ENGINES {
        // calls: eval(1) row0, prox(2), eval(3) row1, prox(4) X
        let mut c = faulty_cluster(engine, 4);
        let err = admm::run(&mut c, &admm::AdmmOptions { rho: 0.1 }, &RunCtx::new(10))
            .expect_err("fault must surface");
        assert_fault_surfaced(err, "admm", engine, 2);
    }
}

#[test]
fn osa_surfaces_fault_with_partial_trace() {
    for engine in ENGINES {
        // calls: eval(1) row0, local_erms(2) X
        let mut c = faulty_cluster(engine, 2);
        let err = osa::run(&mut c, &osa::OsaOptions::default(), &RunCtx::new(1))
            .expect_err("fault must surface");
        assert_fault_surfaced(err, "osa", engine, 1);
    }
}

#[test]
fn osa_bias_corrected_surfaces_fault() {
    for engine in ENGINES {
        let mut c = faulty_cluster(engine, 2);
        let opts = osa::OsaOptions { bias_correction_r: Some(0.5), seed: 1 };
        let err = osa::run(&mut c, &opts, &RunCtx::new(1))
            .expect_err("fault must surface");
        assert_fault_surfaced(err, "osa-bc", engine, 1);
    }
}

#[test]
fn lbfgs_surfaces_fault_with_partial_trace() {
    for engine in ENGINES {
        // calls: grad(1) row0, then probes/grads; 4 lands mid-iteration
        let mut c = faulty_cluster(engine, 4);
        let err = lbfgs::run(&mut c, &lbfgs::LbfgsOptions::default(), &RunCtx::new(10))
            .expect_err("fault must surface");
        assert_fault_surfaced(err, "lbfgs", engine, 1);
    }
}

#[test]
fn algo_error_flattens_into_crate_error() {
    let mut c = faulty_cluster("serial", 4);
    let err = dane_algo::run(&mut c, &dane_algo::DaneOptions::default(), &RunCtx::new(10))
        .expect_err("fault must surface");
    let flat: dane::Error = err.into();
    let msg = flat.to_string();
    // the CLI prints exactly this: algorithm, progress, cause
    assert!(msg.contains("dane failed after"), "{msg}");
    assert!(msg.contains("injected fault"), "{msg}");
}

/// A dataset whose last feature column is identically zero: with
/// lambda = 0 and mu = 0 the cached-Cholesky local solve hits a
/// nonpositive pivot — a *real* worker-side failure, no injection.
fn singular_dataset() -> Dataset {
    let mut rng = Rng64::seed_from_u64(3);
    let mut x = DenseMatrix::zeros(32, 4);
    for i in 0..32 {
        for j in 0..3 {
            x.set(i, j, rng.range_f64(-1.0, 1.0));
        }
    }
    let y: Vec<f64> = (0..32).map(|i| (i % 3) as f64 - 1.0).collect();
    Dataset::new("degenerate", DataMatrix::Dense(x), y)
}

#[test]
fn real_singular_local_solve_fails_cleanly_on_both_engines() {
    let ds = singular_dataset();
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.0));
    for engine in ENGINES {
        let mut c: Box<dyn Cluster> = match engine {
            "serial" => Box::new(SerialCluster::new(&ds, obj.clone(), 4, 1)),
            _ => Box::new(ThreadedCluster::new(&ds, obj.clone(), 4, 1)),
        };
        let err = dane_algo::run(c.as_mut(), &dane_algo::DaneOptions::default(), &RunCtx::new(5))
            .expect_err("singular local solve must surface as Err");
        // the gradient round succeeded and was recorded; the first
        // dane_round killed the run
        assert_eq!(err.trace.len(), 1, "[{engine}]");
        assert!(!err.error.to_string().contains("injected"), "[{engine}]");
    }
}

// ---------------------------------------------------------------------
// TCP engine: a worker child process killed mid-run
// ---------------------------------------------------------------------

use dane::comm::ExecTopology;
use dane::config::LossKind;
use dane::coordinator::tcp::TcpCluster;

/// The engines that can kill a specific worker mid-run: SIGKILL of a
/// real child process (tcp) or the kill switch that makes a worker
/// thread exit silently on its next command (threaded) — both
/// deterministic stand-ins for "the machine died".
trait Killable: Cluster {
    fn kill(&mut self, rank: usize);
}

impl Killable for TcpCluster {
    fn kill(&mut self, rank: usize) {
        self.kill_worker(rank);
    }
}

impl Killable for ThreadedCluster {
    fn kill(&mut self, rank: usize) {
        self.kill_worker(rank);
    }
}

/// Decorator that kills a real worker just before the N-th
/// worker-touching collective call delegates — a deterministic
/// "machine dies mid-run" where timing-based kills would be flaky. The
/// failing call and every later one hit the dead worker, so the error
/// comes from the genuine transport path (dead socket, disconnected
/// channel, or a relay's synthesized error replies under the tree).
struct KillChildAt<C: Killable> {
    inner: C,
    at: usize,
    calls: usize,
    victim: usize,
}

impl<C: Killable> KillChildAt<C> {
    fn tick(&mut self) {
        self.calls += 1;
        if self.calls == self.at {
            self.inner.kill(self.victim);
        }
    }
}

impl<C: Killable> Cluster for KillChildAt<C> {
    fn m(&self) -> usize {
        self.inner.m()
    }
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn objective(&self) -> std::sync::Arc<dyn Objective> {
        self.inner.objective()
    }
    fn grad_and_loss(&mut self, w: &[f64]) -> dane::Result<(Vec<f64>, f64)> {
        self.tick();
        self.inner.grad_and_loss(w)
    }
    fn grad_and_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> dane::Result<f64> {
        self.tick();
        self.inner.grad_and_loss_into(w, g)
    }
    fn loss_only(&mut self, w: &[f64]) -> dane::Result<f64> {
        self.tick();
        self.inner.loss_only(w)
    }
    fn dane_round(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> dane::Result<Vec<f64>> {
        self.tick();
        self.inner.dane_round(w_prev, g, eta, mu)
    }
    fn dane_round_into(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
        out: &mut [f64],
    ) -> dane::Result<()> {
        self.tick();
        self.inner.dane_round_into(w_prev, g, eta, mu, out)
    }
    fn dane_round_first(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> dane::Result<Vec<f64>> {
        self.tick();
        self.inner.dane_round_first(w_prev, g, eta, mu)
    }
    fn prox_all(
        &mut self,
        targets: &[Vec<f64>],
        rho: f64,
    ) -> dane::Result<Vec<Option<Vec<f64>>>> {
        self.tick();
        self.inner.prox_all(targets, rho)
    }
    fn local_erms(
        &mut self,
        subsample: Option<(f64, u64)>,
    ) -> dane::Result<(Vec<Option<Vec<f64>>>, Option<Vec<Option<Vec<f64>>>>)> {
        self.tick();
        self.inner.local_erms(subsample)
    }
    fn allreduce_mean_vecs(&mut self, vecs: &[Vec<f64>]) -> dane::Result<Vec<f64>> {
        self.inner.allreduce_mean_vecs(vecs)
    }
    fn avg_row_sq_norm(&mut self) -> dane::Result<f64> {
        self.tick();
        self.inner.avg_row_sq_norm()
    }
    fn eval_loss(&mut self, w: &[f64]) -> dane::Result<f64> {
        self.tick();
        self.inner.eval_loss(w)
    }
    fn eval_grad_loss(&mut self, w: &[f64]) -> dane::Result<(Vec<f64>, f64)> {
        self.tick();
        self.inner.eval_grad_loss(w)
    }
    fn comm_stats(&self) -> dane::comm::CommStats {
        self.inner.comm_stats()
    }
    fn reset_comm(&mut self) {
        self.inner.reset_comm();
    }
    fn alive(&self) -> usize {
        self.inner.alive()
    }
    fn recover(&mut self, respawn: bool) -> dane::Result<usize> {
        self.inner.recover(respawn)
    }
    fn restore_comm(&mut self, stats: &dane::comm::CommStats) {
        self.inner.restore_comm(stats);
    }
    fn fault_kill_worker(&mut self, rank: usize) {
        self.inner.fault_kill_worker(rank);
    }
    fn enable_recovery(&mut self, ds: &Dataset, shard_seed: u64, gram_threads: Option<usize>) {
        self.inner.enable_recovery(ds, shard_seed, gram_threads);
    }
}

/// Self-hosted 4-process cluster (under `topology`) whose worker
/// `victim` child is killed at worker-touching collective call `at`.
fn tcp_killing_cluster_at(
    at: usize,
    victim: usize,
    topology: ExecTopology,
) -> KillChildAt<TcpCluster> {
    // Env-free override (see tcp_cluster.rs::ensure_worker_bin).
    dane::coordinator::tcp::set_worker_binary(env!("CARGO_BIN_EXE_dane"));
    let ds = synthetic_fig2(256, 6, 0.005, 4);
    let inner = TcpCluster::self_hosted(
        &ds,
        LossKind::Ridge,
        0.01,
        4,
        3,
        dane::comm::NetModel::free(),
        None,
        Some(std::time::Duration::from_secs(10)),
        topology,
    )
    .expect("self-hosted tcp cluster must come up");
    KillChildAt { inner, at, calls: 0, victim }
}

fn tcp_killing_cluster(at: usize) -> KillChildAt<TcpCluster> {
    tcp_killing_cluster_at(at, 2, ExecTopology::Star)
}

/// TCP counterpart of `assert_fault_surfaced`: the cause is a real
/// socket-level failure, not an injected message.
fn assert_tcp_fault_surfaced(err: Box<AlgoError>, algo: &str, min_rows: usize) {
    assert_eq!(err.algo, algo);
    assert!(
        err.trace.len() >= min_rows,
        "[tcp] {algo}: expected >= {min_rows} trace rows before the kill, got {}",
        err.trace.len()
    );
    let cause = err.error.to_string();
    assert!(
        cause.contains("worker"),
        "[tcp] {algo}: cause should name the worker: {cause}"
    );
    assert_eq!(err.w.len(), 6);
}

#[test]
fn tcp_dane_surfaces_child_kill_with_partial_trace() {
    // calls: grad(1) row0, dane_round(2), grad(3) row1, dane_round(4) X
    let mut c = tcp_killing_cluster(4);
    let err = dane_algo::run(&mut c, &dane_algo::DaneOptions::default(), &RunCtx::new(10))
        .expect_err("child kill must surface");
    assert_tcp_fault_surfaced(err, "dane", 2);
}

#[test]
fn tcp_gd_and_agd_surface_child_kill() {
    let mut c = tcp_killing_cluster(4);
    let err = gd::run_gd(&mut c, &gd::GdOptions::default(), &RunCtx::new(10))
        .expect_err("child kill must surface");
    assert_tcp_fault_surfaced(err, "gd", 2);

    let mut c = tcp_killing_cluster(4);
    let err = gd::run_agd(&mut c, &gd::AgdOptions::default(), &RunCtx::new(10))
        .expect_err("child kill must surface");
    assert_tcp_fault_surfaced(err, "agd", 1);
}

#[test]
fn tcp_admm_surfaces_child_kill() {
    let mut c = tcp_killing_cluster(4);
    let err = admm::run(&mut c, &admm::AdmmOptions { rho: 0.1 }, &RunCtx::new(10))
        .expect_err("child kill must surface");
    assert_tcp_fault_surfaced(err, "admm", 2);
}

#[test]
fn tcp_osa_surfaces_child_kill() {
    let mut c = tcp_killing_cluster(2);
    let err = osa::run(&mut c, &osa::OsaOptions::default(), &RunCtx::new(1))
        .expect_err("child kill must surface");
    assert_tcp_fault_surfaced(err, "osa", 1);
}

#[test]
fn tcp_lbfgs_surfaces_child_kill() {
    let mut c = tcp_killing_cluster(4);
    let err = lbfgs::run(&mut c, &lbfgs::LbfgsOptions::default(), &RunCtx::new(10))
        .expect_err("child kill must surface");
    assert_tcp_fault_surfaced(err, "lbfgs", 1);
}

// ---------------------------------------------------------------------
// Tree relay: a SIGKILLed interior (relaying) node must fail every
// algorithm on both concurrent engines — Err with the partial trace
// intact, no hang. m = 4 binomial plan: leader -> {0, 1, 3}, worker 0
// relays for worker 2, so worker 0 is the interior node.
// ---------------------------------------------------------------------

use dane::coordinator::AlgoOutcome;

fn threaded_tree_killing_cluster(
    at: usize,
    victim: usize,
) -> KillChildAt<ThreadedCluster> {
    let ds = synthetic_fig2(256, 6, 0.005, 4);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
    let inner = ThreadedCluster::with_topology(
        &ds,
        obj,
        4,
        3,
        dane::comm::NetModel::free(),
        None,
        ExecTopology::Tree,
    );
    KillChildAt { inner, at, calls: 0, victim }
}

fn run_algo(c: &mut dyn Cluster, algo: &str) -> AlgoOutcome {
    match algo {
        "dane" => dane_algo::run(c, &Default::default(), &RunCtx::new(10)),
        "gd" => gd::run_gd(c, &Default::default(), &RunCtx::new(10)),
        "agd" => gd::run_agd(c, &Default::default(), &RunCtx::new(10)),
        "admm" => admm::run(c, &admm::AdmmOptions { rho: 0.1 }, &RunCtx::new(10)),
        "osa" => osa::run(c, &Default::default(), &RunCtx::new(1)),
        "lbfgs" => lbfgs::run(c, &Default::default(), &RunCtx::new(10)),
        other => panic!("unknown algo {other}"),
    }
}

#[test]
fn tree_relay_interior_kill_fails_every_algorithm_on_both_engines() {
    let cases: [(&str, usize, usize); 6] = [
        ("dane", 4, 2),
        ("gd", 4, 2),
        ("agd", 4, 1),
        ("admm", 4, 2),
        ("osa", 2, 1),
        ("lbfgs", 4, 1),
    ];
    for (algo, at, min_rows) in cases {
        for engine in ["threaded", "tcp"] {
            let out = match engine {
                "threaded" => {
                    let mut c = threaded_tree_killing_cluster(at, 0);
                    run_algo(&mut c, algo)
                }
                _ => {
                    let mut c = tcp_killing_cluster_at(at, 0, ExecTopology::Tree);
                    run_algo(&mut c, algo)
                }
            };
            let err = out.expect_err("interior-node kill must surface as Err");
            assert!(
                err.trace.len() >= min_rows,
                "[{engine}-tree] {algo}: expected >= {min_rows} rows, got {}",
                err.trace.len()
            );
            assert!(
                err.error.to_string().contains("worker"),
                "[{engine}-tree] {algo}: cause should name a worker: {}",
                err.error
            );
            assert_eq!(err.w.len(), 6, "[{engine}-tree] {algo}");
        }
    }
}

#[test]
fn tcp_tree_leaf_behind_relay_kill_surfaces_through_the_relay() {
    // Killing the leaf (worker 2) reached only through worker 0's relay
    // exercises the relay's synthesized-error path over real sockets:
    // worker 0 must keep the frame-count discipline for its dead child.
    let mut c = tcp_killing_cluster_at(4, 2, ExecTopology::Tree);
    let err = dane_algo::run(&mut c, &dane_algo::DaneOptions::default(), &RunCtx::new(10))
        .expect_err("leaf kill must surface through the relay");
    assert_tcp_fault_surfaced(err, "dane", 2);
}

#[test]
fn threaded_tree_leaf_behind_relay_kill_surfaces_through_the_relay() {
    let mut c = threaded_tree_killing_cluster(4, 2);
    let err = dane_algo::run(&mut c, &dane_algo::DaneOptions::default(), &RunCtx::new(10))
        .expect_err("leaf kill must surface through the relay");
    assert_eq!(err.algo, "dane");
    assert!(err.trace.len() >= 2, "got {}", err.trace.len());
    assert!(err.error.to_string().contains("worker"), "{}", err.error);
}

#[test]
fn passthrough_wrapper_preserves_results_bitwise() {
    // Sanity: with the trigger unreachable, the decorator is invisible —
    // same trace as the bare engine, bit for bit.
    let ctx = RunCtx::new(6);
    let mut bare = bare_cluster("serial");
    let plain = dane_algo::run(bare.as_mut(), &dane_algo::DaneOptions::default(), &ctx).unwrap();
    let mut wrapped = FaultInjectCluster::new(bare_cluster("serial"), 0, usize::MAX);
    let decorated = dane_algo::run(&mut wrapped, &dane_algo::DaneOptions::default(), &ctx).unwrap();
    assert_eq!(plain.w, decorated.w);
    assert_eq!(plain.trace.len(), decorated.trace.len());
    for (a, b) in plain.trace.rows.iter().zip(&decorated.trace.rows) {
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.comm_rounds, b.comm_rounds);
    }
}
