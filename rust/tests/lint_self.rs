//! `dane-lint` linting itself: fixture snippets trip every rule, their
//! `lint:allow`-annotated twins pass, marker misuse is reported, and —
//! the test that gives the other suites their teeth — the real tree
//! lints clean through the exact `lint_repo` path CI runs.
//!
//! The fixture repos are built on disk (util::tempdir) so the binary's
//! walk/exit-code contract is exercised end to end, not just the rule
//! functions.

use std::path::Path;
use std::process::Command;

use dane::analysis::{apply_allows, rules, Diagnostic, FileAnalysis};
use dane::util::tempdir::TempDir;

fn fa(rel: &str, src: &str) -> FileAnalysis {
    FileAnalysis::new(rel, src)
}

/// Diagnostics for one file after allow-filtering: what `lint_repo`
/// would report for it.
fn lint_one(rel: &str, src: &str, rule: fn(&FileAnalysis) -> Vec<Diagnostic>) -> Vec<Diagnostic> {
    let f = fa(rel, src);
    apply_allows(rule(&f), &[&f])
}

// ------------------------------------------------- per-file rules

#[test]
fn panic_freedom_trips_and_its_allowed_twin_passes() {
    let bad = "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    let d = lint_one("rust/src/comm/fixture.rs", bad, rules::panic_freedom);
    assert_eq!(d.len(), 1, "{d:?}");
    assert_eq!(d[0].rule, "panic-freedom");
    assert_eq!(d[0].line, 2);

    let twin = "pub fn f(x: Option<u32>) -> u32 {\n    \
                // lint:allow(panic-freedom): fixture twin, justified\n    \
                x.unwrap()\n}\n";
    let d = lint_one("rust/src/comm/fixture.rs", twin, rules::panic_freedom);
    assert!(d.is_empty(), "allowed twin must pass (no stale either): {d:?}");
}

#[test]
fn panic_freedom_exempts_test_scope_and_foreign_paths() {
    let in_tests = "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u32>) { x.unwrap(); }\n}\n";
    assert!(lint_one("rust/src/comm/fixture.rs", in_tests, rules::panic_freedom).is_empty());
    // linalg/ is outside the panic-freedom scope entirely
    let bad = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
    assert!(lint_one("rust/src/linalg/fixture.rs", bad, rules::panic_freedom).is_empty());
}

#[test]
fn densify_trips_and_its_allowed_twin_passes() {
    let bad = "fn f(m: &DataMatrix) {\n    let _ = m.to_dense();\n}\n";
    let d = lint_one("rust/src/solver/fixture.rs", bad, rules::densify);
    assert_eq!(d.len(), 1, "{d:?}");
    assert_eq!(d[0].rule, "densify");

    let twin = "fn f(m: &DataMatrix) {\n    \
                let _ = m.to_dense(); // lint:allow(densify): d is tiny here by contract\n}\n";
    assert!(lint_one("rust/src/solver/fixture.rs", twin, rules::densify).is_empty());
    // inside linalg/ the call is the implementation, not a violation
    assert!(lint_one("rust/src/linalg/fixture.rs", bad, rules::densify).is_empty());
}

#[test]
fn determinism_trips_on_clocks_and_hash_iteration() {
    let clock = "fn f() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
    let d = lint_one("rust/src/solver/fixture.rs", clock, rules::determinism);
    assert_eq!(d.len(), 1, "{d:?}");
    assert_eq!(d[0].rule, "determinism");
    // the timing allowlist really does exempt the metrics clocks
    assert!(lint_one("rust/src/util/bench.rs", clock, rules::determinism).is_empty());

    let iter = "use std::collections::HashMap;\n\
                fn f(m: &HashMap<String, u64>) -> u64 {\n    m.values().sum()\n}\n";
    let d = lint_one("rust/src/solver/fixture.rs", iter, rules::determinism);
    assert_eq!(d.len(), 1, "{d:?}");
    assert!(d[0].msg.contains("values"), "{d:?}");

    // ordered maps iterate deterministically: not a violation
    let btree = "use std::collections::BTreeMap;\n\
                 fn f(m: &BTreeMap<String, u64>) -> u64 {\n    m.values().sum()\n}\n";
    assert!(lint_one("rust/src/solver/fixture.rs", btree, rules::determinism).is_empty());
}

#[test]
fn marker_misuse_is_itself_a_violation() {
    // unknown rule
    let d = lint_one(
        "rust/src/comm/fixture.rs",
        "// lint:allow(bogus-rule): why\nfn f() {}\n",
        rules::panic_freedom,
    );
    assert_eq!(d.len(), 1, "{d:?}");
    assert_eq!(d[0].rule, "lint-allow");
    assert!(d[0].msg.contains("unknown rule"), "{d:?}");

    // missing reason
    let d = lint_one(
        "rust/src/comm/fixture.rs",
        "// lint:allow(panic-freedom)\nfn f() {}\n",
        rules::panic_freedom,
    );
    assert_eq!(d.len(), 1, "{d:?}");
    assert!(d[0].msg.contains("needs a reason"), "{d:?}");

    // allow that suppresses nothing has gone stale
    let d = lint_one(
        "rust/src/comm/fixture.rs",
        "fn f() {\n    // lint:allow(panic-freedom): fixed long ago\n    let _x = 1;\n}\n",
        rules::panic_freedom,
    );
    assert_eq!(d.len(), 1, "{d:?}");
    assert!(d[0].msg.contains("stale"), "{d:?}");
}

// ------------------------------------------------- fixture repos on disk

/// A minimal repo that lints clean: a complete two-variant wire
/// protocol with hostile-bytes coverage, an agreeing TraceRow/header
/// pair, and a ci.yml whose column indices are in range.
fn write_clean_repo(root: &Path) {
    let w = |rel: &str, content: &str| {
        let p = root.join(rel);
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(p, content).unwrap();
    };
    w(
        "rust/src/comm/wire.rs",
        "pub const CMD_INIT: u8 = 0x01;\n\
         pub const REP_VEC: u8 = 0x81;\n\
         pub enum Command {\n    Init(Vec<u8>),\n}\n\
         pub enum Reply {\n    Vec(Vec<f64>),\n}\n\
         fn put(buf: &mut Vec<u8>, c: &Command) {\n\
         \x20   match c {\n        Command::Init(_) => buf.push(CMD_INIT),\n    }\n}\n\
         fn put_reply(buf: &mut Vec<u8>, r: &Reply) {\n\
         \x20   match r {\n        Reply::Vec(_) => buf.push(REP_VEC),\n    }\n}\n\
         fn take(tag: u8) -> Result<(), ()> {\n\
         \x20   match tag {\n        CMD_INIT => Ok(()),\n        REP_VEC => Ok(()),\n\
         \x20       _ => Err(()),\n    }\n}\n",
    );
    w(
        "rust/tests/wire_codec.rs",
        "#[test]\nfn truncated_frames_rejected() {\n\
         \x20   let _c = Command::Init(vec![]);\n    let _r = Reply::Vec(vec![]);\n}\n",
    );
    w(
        "rust/src/metrics/trace.rs",
        "pub struct TraceRow {\n    pub round: usize,\n    pub objective: f64,\n}\n",
    );
    w(
        "rust/src/metrics/emit.rs",
        "pub const CSV_HEADER: &str = \"round,objective\";\n\
         fn row() {\n    let _ = format!(\"{},{:.17e}\", 1, 2.0);\n}\n",
    );
    w(
        ".github/workflows/ci.yml",
        "run: awk -F, '{print $2}' trace.csv | cut -d, -f1-2 # objective (2)\n",
    );
}

#[test]
fn fixture_repo_lints_clean_through_lint_repo() {
    let dir = TempDir::new("lint-clean").unwrap();
    write_clean_repo(dir.path());
    let d = dane::analysis::lint_repo(dir.path()).unwrap();
    assert!(d.is_empty(), "{d:?}");
}

#[test]
fn cross_file_rules_trip_on_broken_fixture_repo() {
    let dir = TempDir::new("lint-broken").unwrap();
    write_clean_repo(dir.path());
    // break the wire: a variant with no tag/encode/decode/coverage
    let wire = dir.path().join("rust/src/comm/wire.rs");
    let src = std::fs::read_to_string(&wire).unwrap();
    std::fs::write(&wire, src.replace("    Init(Vec<u8>),\n", "    Init(Vec<u8>),\n    RowSq,\n"))
        .unwrap();
    // break the csv: ci.yml reads a column past the header
    std::fs::write(
        dir.path().join(".github/workflows/ci.yml"),
        "run: awk -F, '{print $9}' trace.csv\n",
    )
    .unwrap();
    let d = dane::analysis::lint_repo(dir.path()).unwrap();
    let msgs: Vec<&str> = d.iter().map(|x| x.msg.as_str()).collect();
    assert!(
        msgs.iter().any(|m| m.contains("`Command::RowSq` has no tag constant")),
        "{msgs:?}"
    );
    assert!(msgs.iter().any(|m| m.contains("`$9` is out of range")), "{msgs:?}");
    assert!(d.iter().all(|x| x.rule == "wire-totality" || x.rule == "csv-schema"), "{d:?}");
}

// ------------------------------------------------- the binary contract

#[test]
fn binary_fails_with_file_line_diagnostics_then_passes_once_allowed() {
    let dir = TempDir::new("lint-bin").unwrap();
    write_clean_repo(dir.path());
    let bad = dir.path().join("rust/src/comm/bad.rs");
    std::fs::write(&bad, "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n").unwrap();

    let out = Command::new(env!("CARGO_BIN_EXE_dane-lint"))
        .args(["--root"])
        .arg(dir.path())
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(1), "stdout: {stdout}");
    assert!(
        stdout.contains("rust/src/comm/bad.rs:2: panic-freedom:"),
        "diagnostics must be file:line-addressed: {stdout}"
    );
    assert!(stdout.contains("1 violation(s)"), "{stdout}");

    std::fs::write(
        &bad,
        "pub fn f(x: Option<u32>) -> u32 {\n    \
         // lint:allow(panic-freedom): fixture, input is produced in-process\n    \
         x.unwrap()\n}\n",
    )
    .unwrap();
    let out = Command::new(env!("CARGO_BIN_EXE_dane-lint"))
        .args(["--root"])
        .arg(dir.path())
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stdout));
}

// ------------------------------------------------- the real tree

/// The gate itself: the repository this test compiles from has zero
/// violations. Every diagnostic below is a regression against an
/// invariant the tree has held since the rule landed.
#[test]
fn the_real_repo_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap();
    let d = dane::analysis::lint_repo(root).unwrap();
    assert!(
        d.is_empty(),
        "dane-lint found violations in the real tree:\n{}",
        d.iter().map(|x| format!("  {x}\n")).collect::<String>()
    );

    let out = Command::new(env!("CARGO_BIN_EXE_dane-lint"))
        .args(["--root"])
        .arg(root)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stdout));
    assert!(String::from_utf8_lossy(&out.stdout).contains("clean"));
}
