//! Sparse-at-scale data plane: DANE on a d = n = 10^5 sparse ridge
//! instance completes on every engine, with the dense d x d Gram /
//! Cholesky path **never** built (80 GB at this dimension — any
//! densification would OOM long before the assert fails).
//!
//! * On the serial engine the matrix-free pin is direct:
//!   `Worker::quad_cache_built()` stays false on every worker after
//!   full DANE rounds.
//! * Threaded and tcp runs are pinned transitively: their traces must
//!   be bit-identical to the serial run's, and the serial run is
//!   proven matrix-free — an engine that densified would either die or
//!   diverge bitwise.
//!
//! Self-hosted tcp clusters need the `dane` binary for their worker
//! children (see tcp_cluster.rs).

use dane::comm::{ExecTopology, NetModel};
use dane::config::LossKind;
use dane::coordinator::tcp::TcpCluster;
use dane::coordinator::threaded::ThreadedCluster;
use dane::coordinator::{dane as dane_algo, Cluster, RunCtx, SerialCluster};
use dane::data::sparse_ridge;
use dane::loss::{Objective, Ridge};
use dane::metrics::Trace;
use std::sync::Arc;

const N: usize = 100_000;
const D: usize = 100_000;
const NNZ: usize = 3;
const M: usize = 4;
const LAMBDA: f64 = 0.1;
const ROUNDS: usize = 2;

fn ensure_worker_bin() {
    dane::coordinator::tcp::set_worker_binary(env!("CARGO_BIN_EXE_dane"));
}

fn big_sparse() -> dane::data::Dataset {
    sparse_ridge(N, D, NNZ, 91)
}

fn run_dane(cluster: &mut dyn Cluster) -> Trace {
    let ctx = RunCtx::new(ROUNDS).with_tol(0.0);
    dane_algo::run(cluster, &Default::default(), &ctx)
        .expect("sparse DANE round failed")
        .trace
}

fn assert_rows_identical_mod_wire(a: &Trace, b: &Trace, tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}");
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.objective, rb.objective, "{tag} round {}", ra.round);
        assert_eq!(ra.grad_norm, rb.grad_norm, "{tag} round {}", ra.round);
        assert_eq!(ra.comm_rounds, rb.comm_rounds, "{tag} round {}", ra.round);
        assert_eq!(ra.comm_bytes, rb.comm_bytes, "{tag} round {}", ra.round);
    }
}

/// The direct pin: serial DANE at d = 10^5 leaves every worker's
/// QuadCache unbuilt — sparse shards take the matrix-free Newton-CG
/// local solve at any dimension.
#[test]
fn serial_sparse_run_never_builds_the_dense_quad_cache() {
    let ds = big_sparse();
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(LAMBDA));
    let mut cluster = SerialCluster::new(&ds, obj, M, 7);
    let trace = run_dane(&mut cluster);
    assert_eq!(trace.len(), ROUNDS + 1);
    // the objective must actually improve — this is a real solve, not
    // a no-op that trivially avoids the cache
    let first = trace.rows.first().unwrap().objective;
    let last = trace.rows.last().unwrap().objective;
    assert!(last < first, "no progress: {first} -> {last}");
    for (i, w) in cluster.workers().iter().enumerate() {
        assert!(
            !w.quad_cache_built(),
            "worker {i} built a dense {D}x{D} Gram on a sparse shard"
        );
    }
}

/// Transitive pin: threaded and tcp traces are bit-identical to the
/// serial (proven matrix-free) run on the same 10^5-dim instance.
#[test]
fn threaded_and_tcp_sparse_runs_match_serial_bitwise() {
    ensure_worker_bin();
    let ds = big_sparse();
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(LAMBDA));

    let mut serial = SerialCluster::new(&ds, obj.clone(), M, 7);
    let reference = run_dane(&mut serial);
    drop(serial);

    let mut threaded = ThreadedCluster::with_topology(
        &ds,
        obj,
        M,
        7,
        NetModel::free(),
        None,
        ExecTopology::Star,
    );
    let tr = run_dane(&mut threaded);
    drop(threaded);
    assert_rows_identical_mod_wire(&reference, &tr, "threaded");

    let mut tcp = TcpCluster::self_hosted(
        &ds,
        LossKind::Ridge,
        LAMBDA,
        M,
        7,
        NetModel::free(),
        None,
        None,
        ExecTopology::Star,
    )
    .unwrap();
    let tt = run_dane(&mut tcp);
    assert_rows_identical_mod_wire(&reference, &tt, "tcp");
    // by-value startup on a 3e5-nnz dataset is real data distribution
    let stats = tcp.comm_stats();
    assert!(
        stats.startup_bytes > (N * NNZ * 8) as u64 / 2,
        "startup_bytes {} is implausibly small for {} nnz shipped by value",
        stats.startup_bytes,
        N * NNZ
    );
}
