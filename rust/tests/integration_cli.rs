//! CLI launcher integration: drive the compiled `dane` binary end to end
//! (arg parsing, config loading, CSV emission, exit codes).

use dane::util::tempdir::TempDir;
use std::process::Command;

fn dane_bin() -> &'static str {
    env!("CARGO_BIN_EXE_dane")
}

#[test]
fn help_prints_usage() {
    let out = Command::new(dane_bin()).arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));
    assert!(text.contains("fig2"));
}

#[test]
fn unknown_subcommand_fails_with_usage() {
    let out = Command::new(dane_bin()).arg("bogus").output().unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("unknown subcommand"));
}

#[test]
fn missing_config_flag_fails() {
    let out = Command::new(dane_bin()).arg("run").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn run_experiment_from_json_config_with_csv() {
    let dir = TempDir::new("cli").unwrap();
    let cfg_path = dir.path().join("exp.json");
    let csv_path = dir.path().join("trace.csv");
    std::fs::write(
        &cfg_path,
        r#"{
          "name": "cli-test",
          "dataset": {"kind": "fig2", "n": 512, "d": 8, "paper_reg": 0.005},
          "loss": "ridge",
          "lambda": 0.01,
          "algo": {"kind": "dane", "eta": 1.0, "mu_over_lambda": 0.0},
          "machines": 4,
          "rounds": 15,
          "tol": 1e-8,
          "seed": 3
        }"#,
    )
    .unwrap();
    let out = Command::new(dane_bin())
        .args([
            "run",
            "--config",
            cfg_path.to_str().unwrap(),
            "--csv",
            csv_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("rounds to 1e-8"), "{text}");
    let csv = std::fs::read_to_string(&csv_path).unwrap();
    assert!(csv.starts_with("round,objective"));
    assert!(csv.lines().count() > 2);
}

#[test]
fn bad_config_reports_error() {
    let dir = TempDir::new("cli-bad").unwrap();
    let cfg_path = dir.path().join("bad.json");
    std::fs::write(&cfg_path, r#"{"name": "x"}"#).unwrap();
    let out = Command::new(dane_bin())
        .args(["run", "--config", cfg_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("missing JSON key"), "{text}");
}

#[test]
fn thm1_subcommand_runs() {
    let out = Command::new(dane_bin())
        .args(["thm1", "--reps", "20"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("F-subopt"), "{text}");
}
