//! CLI launcher integration: drive the compiled `dane` binary end to end
//! (arg parsing, config loading, CSV emission, exit codes).

use dane::util::tempdir::TempDir;
use std::process::Command;

fn dane_bin() -> &'static str {
    env!("CARGO_BIN_EXE_dane")
}

#[test]
fn help_prints_usage() {
    let out = Command::new(dane_bin()).arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));
    assert!(text.contains("fig2"));
}

#[test]
fn unknown_subcommand_fails_with_usage() {
    let out = Command::new(dane_bin()).arg("bogus").output().unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("unknown subcommand"));
    assert!(text.contains("USAGE"), "{text}");
}

#[test]
fn unknown_flag_fails_with_usage() {
    // A typo'd flag must not be silently ignored (it would change the run).
    let out = Command::new(dane_bin())
        .args(["thm1", "--rep", "20"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("unknown flag"), "{text}");
    assert!(text.contains("USAGE"), "{text}");

    // boolean-style unknown flags are rejected too
    let out = Command::new(dane_bin())
        .args(["quickstart", "--verbose"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("unknown flag"), "{text}");
}

#[test]
fn value_flag_without_value_fails() {
    // `--scale` swallowed by `--out` must not silently default to scale=1.
    let out = Command::new(dane_bin())
        .args(["fig2", "--scale", "--out", "results"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--scale requires a value"), "{text}");

    // trailing value flag with no value at all
    let out = Command::new(dane_bin())
        .args(["thm1", "--reps"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--reps requires a value"), "{text}");
}

#[test]
fn bool_flag_with_value_fails() {
    let out = Command::new(dane_bin())
        .args(["run", "--config", "c.json", "--quiet", "extra"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--quiet does not take a value"), "{text}");
}

#[test]
fn zero_scale_and_reps_fail_loudly() {
    // `--scale 0` / `--reps 0` used to be silently clamped to 1 — they
    // are malformed input and must fail with USAGE + non-zero exit.
    let out = Command::new(dane_bin())
        .args(["fig2", "--scale", "0"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--scale must be >= 1"), "{text}");
    assert!(text.contains("USAGE"), "{text}");

    let out = Command::new(dane_bin())
        .args(["thm1", "--reps", "0"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--reps must be >= 1"), "{text}");
    assert!(text.contains("USAGE"), "{text}");
}

#[test]
fn unknown_engine_fails_with_usage() {
    let out = Command::new(dane_bin())
        .args(["quickstart", "--engine", "bogus"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("unknown engine"), "{text}");
    assert!(text.contains("USAGE"), "{text}");
}

#[test]
fn no_subcommand_fails_with_usage() {
    let out = Command::new(dane_bin()).output().unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("missing subcommand"), "{text}");
    assert!(text.contains("USAGE"), "{text}");
}

#[test]
fn quickstart_runs_and_exits_zero() {
    let out = Command::new(dane_bin()).arg("quickstart").output().unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("quickstart"), "{text}");
    assert!(text.contains("converged"), "{text}");
}

#[test]
fn quickstart_runs_on_threaded_engine() {
    let out = Command::new(dane_bin())
        .args(["quickstart", "--engine", "threaded"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("engine: threaded"), "{text}");
    assert!(text.contains("converged"), "{text}");
}

#[test]
fn missing_config_flag_fails() {
    let out = Command::new(dane_bin()).arg("run").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn quickstart_runs_tree_topology_on_threaded_engine() {
    let out = Command::new(dane_bin())
        .args(["quickstart", "--engine", "threaded", "--topology", "tree"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("topology: tree"), "{text}");
    assert!(text.contains("converged: true"), "{text}");
}

#[test]
fn unknown_topology_fails_with_usage() {
    let out = Command::new(dane_bin())
        .args(["quickstart", "--topology", "ring"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("unknown topology"), "{text}");
    assert!(text.contains("USAGE"), "{text}");
}

#[test]
fn run_experiment_from_json_config_with_csv() {
    let dir = TempDir::new("cli").unwrap();
    let cfg_path = dir.path().join("exp.json");
    let csv_path = dir.path().join("trace.csv");
    std::fs::write(
        &cfg_path,
        r#"{
          "name": "cli-test",
          "dataset": {"kind": "fig2", "n": 512, "d": 8, "paper_reg": 0.005},
          "loss": "ridge",
          "lambda": 0.01,
          "algo": {"kind": "dane", "eta": 1.0, "mu_over_lambda": 0.0},
          "machines": 4,
          "rounds": 15,
          "tol": 1e-8,
          "seed": 3
        }"#,
    )
    .unwrap();
    let out = Command::new(dane_bin())
        .args([
            "run",
            "--config",
            cfg_path.to_str().unwrap(),
            "--csv",
            csv_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("rounds to 1e-8"), "{text}");
    let csv = std::fs::read_to_string(&csv_path).unwrap();
    assert!(csv.starts_with("round,objective"));
    assert!(csv.lines().count() > 2);
}

#[test]
fn run_config_selects_threaded_engine() {
    // The same experiment through `engine: threaded` must succeed and
    // emit a CSV like the serial path does.
    let dir = TempDir::new("cli-threaded").unwrap();
    let cfg_path = dir.path().join("exp.json");
    let csv_path = dir.path().join("trace.csv");
    std::fs::write(
        &cfg_path,
        r#"{
          "name": "cli-threaded",
          "dataset": {"kind": "fig2", "n": 512, "d": 8, "paper_reg": 0.005},
          "loss": "ridge",
          "lambda": 0.01,
          "algo": {"kind": "dane", "eta": 1.0, "mu_over_lambda": 0.0},
          "machines": 4,
          "rounds": 15,
          "tol": 1e-8,
          "seed": 3,
          "engine": "threaded",
          "threads": 2
        }"#,
    )
    .unwrap();
    let out = Command::new(dane_bin())
        .args([
            "run",
            "--config",
            cfg_path.to_str().unwrap(),
            "--csv",
            csv_path.to_str().unwrap(),
            "--quiet",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let csv = std::fs::read_to_string(&csv_path).unwrap();
    assert!(csv.starts_with("round,objective"));
    assert!(csv.lines().count() > 2);
}

#[test]
fn invalid_engine_config_reports_error() {
    let dir = TempDir::new("cli-bad-engine").unwrap();
    let cfg_path = dir.path().join("bad.json");
    std::fs::write(
        &cfg_path,
        r#"{
          "name": "bad-engine",
          "dataset": {"kind": "fig2", "n": 64, "d": 4, "paper_reg": 0.005},
          "loss": "ridge",
          "lambda": 0.01,
          "algo": {"kind": "dane", "eta": 1.0, "mu_over_lambda": 0.0},
          "machines": 2,
          "rounds": 5,
          "engine": "quantum"
        }"#,
    )
    .unwrap();
    let out = Command::new(dane_bin())
        .args(["run", "--config", cfg_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("unknown engine"), "{text}");
}

#[test]
fn bad_config_reports_error() {
    let dir = TempDir::new("cli-bad").unwrap();
    let cfg_path = dir.path().join("bad.json");
    std::fs::write(&cfg_path, r#"{"name": "x"}"#).unwrap();
    let out = Command::new(dane_bin())
        .args(["run", "--config", cfg_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("missing JSON key"), "{text}");
}

/// Write a minimal serial fig2 config and return its path.
fn write_small_cfg(dir: &TempDir) -> std::path::PathBuf {
    let cfg_path = dir.path().join("exp.json");
    std::fs::write(
        &cfg_path,
        r#"{
          "name": "cli-engine-flag",
          "dataset": {"kind": "fig2", "n": 256, "d": 6, "paper_reg": 0.005},
          "loss": "ridge",
          "lambda": 0.01,
          "algo": {"kind": "dane", "eta": 1.0, "mu_over_lambda": 0.0},
          "machines": 2,
          "rounds": 8,
          "tol": 1e-8,
          "seed": 3
        }"#,
    )
    .unwrap();
    cfg_path
}

#[test]
fn run_engine_flag_overrides_config() {
    // The config says nothing (defaults to serial); --engine threaded
    // must run the threaded engine and still succeed.
    let dir = TempDir::new("cli-engine-flag").unwrap();
    let cfg_path = write_small_cfg(&dir);
    let out = Command::new(dane_bin())
        .args([
            "run",
            "--config",
            cfg_path.to_str().unwrap(),
            "--engine",
            "threaded",
            "--quiet",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn run_engine_flag_rejects_unknown_value() {
    let dir = TempDir::new("cli-engine-bad").unwrap();
    let cfg_path = write_small_cfg(&dir);
    let out = Command::new(dane_bin())
        .args(["run", "--config", cfg_path.to_str().unwrap(), "--engine", "quantum"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("unknown engine"), "{text}");
    assert!(text.contains("USAGE"), "{text}");
}

#[test]
fn run_engine_tcp_self_hosts_workers_and_emits_wire_bytes() {
    // `--engine tcp` with no workers list: the CLI leader spawns its own
    // worker processes on loopback and the CSV gains a measured
    // wire_bytes column with nonzero entries.
    let dir = TempDir::new("cli-tcp").unwrap();
    let cfg_path = write_small_cfg(&dir);
    let csv_path = dir.path().join("trace.csv");
    let out = Command::new(dane_bin())
        .args([
            "run",
            "--config",
            cfg_path.to_str().unwrap(),
            "--engine",
            "tcp",
            "--csv",
            csv_path.to_str().unwrap(),
            "--quiet",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let csv = std::fs::read_to_string(&csv_path).unwrap();
    let mut lines = csv.lines();
    let header = lines.next().unwrap();
    assert!(
        header.ends_with(
            ",elapsed_seconds,wire_bytes,startup_bytes,alive_workers,recoveries"
        ),
        "{header}"
    );
    let last = lines.last().unwrap();
    let mut tail = last.rsplit(',');
    let recoveries: u64 = tail.next().unwrap().parse().unwrap();
    let alive: u64 = tail.next().unwrap().parse().unwrap();
    let startup: u64 = tail.next().unwrap().parse().unwrap();
    let wire: u64 = tail.next().unwrap().parse().unwrap();
    assert_eq!(recoveries, 0, "fault-free run recorded a recovery: {last}");
    assert_eq!(alive, 2, "fault-free run lost workers: {last}");
    assert!(wire > 0, "tcp run recorded no measured bytes: {last}");
    assert!(startup > 0, "tcp run recorded no startup bytes: {last}");
}

#[test]
fn worker_subcommand_requires_listen() {
    let out = Command::new(dane_bin()).arg("worker").output().unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--listen"), "{text}");
    assert!(text.contains("USAGE"), "{text}");
}

#[test]
fn worker_announces_bound_address() {
    // `dane worker --listen 127.0.0.1:0 --once` must print the resolved
    // port and exit cleanly once the leader (us) connects and hangs up.
    // (Without --once the worker loops back to accept — fault-tolerant
    // default since the respawn policy redials external workers.)
    let mut child = Command::new(dane_bin())
        .args(["worker", "--listen", "127.0.0.1:0", "--once"])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    let stdout = child.stdout.take().unwrap();
    let mut line = String::new();
    use std::io::BufRead;
    std::io::BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr = line
        .trim()
        .strip_prefix("listening on ")
        .unwrap_or_else(|| panic!("bad announce line: {line:?}"));
    let stream = std::net::TcpStream::connect(addr).unwrap();
    drop(stream); // leader hangs up at a frame boundary -> clean exit
    let status = child.wait().unwrap();
    assert!(status.success(), "worker exit: {status:?}");
}

#[test]
fn thm1_subcommand_runs() {
    let out = Command::new(dane_bin())
        .args(["thm1", "--reps", "20"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("F-subopt"), "{text}");
}
