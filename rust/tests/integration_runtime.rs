//! PJRT backend integration: the AOT artifacts (jax/Pallas, lowered at
//! build time by `make artifacts`) must agree with the native rust
//! implementations on the same shards, and a full DANE run on the PJRT
//! backend must converge like the native one.
//!
//! Requires `artifacts/` AND a real PJRT runtime. The offline build
//! ships neither (see `dane::xla`), so every test here degrades to an
//! explicit skip when the registry cannot be opened — the suite stays
//! green without the python layer, which is build-time-optional.

use dane::config::LossKind;
use dane::coordinator::dane as dane_algo;
use dane::coordinator::{Cluster, RunCtx, SerialCluster};
use dane::data::{shard_dataset, synthetic_fig2};
use dane::linalg::ops;
use dane::loss::{make_objective, Objective, Ridge, SmoothHinge};
use dane::runtime::{ArtifactRegistry, PjrtSession};
use dane::solver::erm_solve;
use dane::worker::{Worker, WorkerBackend};
use std::path::Path;
use std::sync::Arc;

/// Where `python -m compile.aot --out ../artifacts` puts the artifacts:
/// the repo root, one level above this crate. Fall back to an in-crate
/// `rust/artifacts` for manually placed trees.
fn artifact_dir() -> std::path::PathBuf {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    if root.exists() {
        root
    } else {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }
}

/// Open the artifact registry, or None (with a skip note) when the
/// artifacts were never built or the PJRT runtime is the offline stub.
/// Any *other* open failure — artifacts exist but the manifest is
/// corrupt, an entry is missing, etc. — is a real regression and panics
/// instead of silently greening the suite.
fn registry() -> Option<Arc<ArtifactRegistry>> {
    let dir = artifact_dir();
    if !dir.exists() {
        eprintln!("skipping PJRT test: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    match ArtifactRegistry::open(&dir) {
        Ok(reg) => Some(Arc::new(reg)),
        Err(e) if e.to_string().contains("PJRT runtime is unavailable") => {
            eprintln!("skipping PJRT test ({e})");
            None
        }
        Err(e) => panic!("artifacts/ exists but cannot be opened: {e}"),
    }
}

macro_rules! registry_or_skip {
    () => {
        match registry() {
            Some(r) => r,
            None => return,
        }
    };
}

/// f32 path vs f64 path: tolerances are relative, driven by f32 eps.
fn assert_close(a: &[f64], b: &[f64], rtol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let scale = ops::norm2(b).max(1.0);
    for i in 0..a.len() {
        assert!(
            (a[i] - b[i]).abs() <= rtol * scale,
            "{what}[{i}]: {} vs {} (scale {scale})",
            a[i],
            b[i]
        );
    }
}

#[test]
fn manifest_lists_all_entry_families() {
    let reg = registry_or_skip!();
    let names: Vec<&str> = reg
        .manifest()
        .entries
        .iter()
        .map(|e| e.name.as_str())
        .collect();
    for family in [
        "ridge_grad",
        "ridge_local_solve",
        "hinge_grad_loss",
        "hinge_local_solve",
    ] {
        assert!(
            names.iter().any(|n| n.starts_with(family)),
            "missing {family} in {names:?}"
        );
    }
}

#[test]
fn ridge_grad_pjrt_matches_native() {
    let reg = registry_or_skip!();
    let ds = synthetic_fig2(200, 48, 0.005, 3); // pads to 256 x 64
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
    let shards = shard_dataset(&ds, 2, 7);
    for shard in &shards {
        let session =
            PjrtSession::for_shard(reg.clone(), shard, obj.as_ref()).unwrap();
        assert_eq!(session.padded_shape(), (256, 64));

        let w: Vec<f64> = (0..48).map(|i| 0.02 * i as f64 - 0.5).collect();
        let mut g_pjrt = vec![0.0; 48];
        let loss_pjrt = session.grad(shard, obj.as_ref(), &w, &mut g_pjrt).unwrap();

        let mut g_native = vec![0.0; 48];
        let mut rowbuf = vec![0.0; shard.n()];
        let loss_native = obj.value_grad(shard, &w, &mut g_native, &mut rowbuf);

        assert_close(&g_pjrt, &g_native, 1e-4, "ridge grad");
        assert!(
            (loss_pjrt - loss_native).abs() <= 1e-4 * loss_native.abs().max(1.0),
            "{loss_pjrt} vs {loss_native}"
        );
    }
}

#[test]
fn hinge_grad_pjrt_matches_native() {
    let reg = registry_or_skip!();
    let ds = dane::data::covtype_like(180, 16, 5); // d=54 -> pads to 256x64
    let obj: Arc<dyn Objective> = Arc::new(SmoothHinge::new(1e-3));
    let shards = shard_dataset(&ds, 2, 9);
    for shard in &shards {
        let session =
            PjrtSession::for_shard(reg.clone(), shard, obj.as_ref()).unwrap();
        let w: Vec<f64> =
            (0..54).map(|i| ((i * 7) % 13) as f64 * 0.01 - 0.05).collect();
        let mut g_pjrt = vec![0.0; 54];
        let loss_pjrt = session.grad(shard, obj.as_ref(), &w, &mut g_pjrt).unwrap();

        let mut g_native = vec![0.0; 54];
        let mut rowbuf = vec![0.0; shard.n()];
        let loss_native = obj.value_grad(shard, &w, &mut g_native, &mut rowbuf);

        assert_close(&g_pjrt, &g_native, 1e-4, "hinge grad");
        assert!((loss_pjrt - loss_native).abs() <= 1e-4 * loss_native.max(1.0));
    }
}

#[test]
fn ridge_dane_local_solve_pjrt_matches_native() {
    let reg = registry_or_skip!();
    let ds = synthetic_fig2(220, 40, 0.005, 11);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
    let shards = shard_dataset(&ds, 2, 3);
    let shard = &shards[0];

    // global gradient from the full data at w_prev
    let w_prev: Vec<f64> = (0..40).map(|i| 0.01 * i as f64).collect();
    let all = ds.as_single_shard();
    let mut g = vec![0.0; 40];
    let mut rowbuf = vec![0.0; all.n()];
    obj.value_grad(&all, &w_prev, &mut g, &mut rowbuf);

    let session = PjrtSession::for_shard(reg.clone(), shard, obj.as_ref()).unwrap();
    let w_pjrt = session
        .dane_local_solve(shard, obj.as_ref(), &w_prev, &g, 1.0, 0.005)
        .unwrap();

    let mut worker = Worker::new(0, shard.clone(), obj.clone());
    let w_native = worker.dane_local_solve(&w_prev, &g, 1.0, 0.005).unwrap();

    assert_close(&w_pjrt, &w_native, 5e-4, "ridge dane local solve");
}

#[test]
fn hinge_dane_local_solve_pjrt_matches_native() {
    let reg = registry_or_skip!();
    let ds = dane::data::covtype_like(200, 16, 7);
    let lam = 1e-2;
    let obj: Arc<dyn Objective> = Arc::new(SmoothHinge::new(lam));
    let shards = shard_dataset(&ds, 2, 5);
    let shard = &shards[0];

    let w_prev = vec![0.05; 54];
    let all = ds.as_single_shard();
    let mut g = vec![0.0; 54];
    let mut rowbuf = vec![0.0; all.n()];
    obj.value_grad(&all, &w_prev, &mut g, &mut rowbuf);

    let session = PjrtSession::for_shard(reg.clone(), shard, obj.as_ref()).unwrap();
    let w_pjrt = session
        .dane_local_solve(shard, obj.as_ref(), &w_prev, &g, 1.0, 3.0 * lam)
        .unwrap();

    let mut worker = Worker::new(0, shard.clone(), obj.clone());
    let w_native = worker.dane_local_solve(&w_prev, &g, 1.0, 3.0 * lam).unwrap();

    // Newton-CG on f32 vs f64: looser but still tight in relative terms.
    assert_close(&w_pjrt, &w_native, 5e-3, "hinge dane local solve");
}

#[test]
fn full_dane_run_on_pjrt_backend_converges() {
    let reg = registry_or_skip!();
    let ds = synthetic_fig2(240, 32, 0.005, 21);
    let lam = dane::data::synthetic::fig2_lambda(0.005);
    let obj = make_objective(LossKind::Ridge, lam);
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();

    let mut cluster = SerialCluster::new(&ds, obj, 2, 5);
    cluster.use_pjrt(reg).unwrap();
    // f32 artifacts floor the reachable suboptimality around 1e-6..1e-7.
    let ctx = RunCtx::new(12).with_reference(phi_star).with_tol(5e-6);
    let res = dane_algo::run(&mut cluster, &dane_algo::DaneOptions::default(), &ctx).unwrap();
    assert!(
        res.converged,
        "pjrt DANE should reach 5e-6: {:?}",
        res.trace.suboptimality()
    );
    assert_eq!(cluster.m(), 2);
}

#[test]
fn pjrt_worker_backend_grad_through_worker_api() {
    let reg = registry_or_skip!();
    let ds = synthetic_fig2(100, 20, 0.005, 31);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
    let shards = shard_dataset(&ds, 1, 1);
    let shard = shards.into_iter().next().unwrap();
    let session = PjrtSession::for_shard(reg, &shard, obj.as_ref()).unwrap();
    let mut worker = Worker::new(0, shard.clone(), obj.clone())
        .with_backend(WorkerBackend::Pjrt(Arc::new(session)));
    let w = vec![0.1; 20];
    let mut g1 = vec![0.0; 20];
    let l1 = worker.grad(&w, &mut g1).unwrap();
    let mut g2 = vec![0.0; 20];
    let mut rowbuf = vec![0.0; shard.n()];
    let l2 = obj.value_grad(&shard, &w, &mut g2, &mut rowbuf);
    assert_close(&g1, &g2, 1e-4, "worker pjrt grad");
    assert!((l1 - l2).abs() < 1e-4 * l2.abs().max(1.0));
}

#[test]
fn oversized_shard_is_rejected() {
    let reg = registry_or_skip!();
    let ds = synthetic_fig2(64, 600, 0.005, 41); // d=600 > largest artifact d
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
    let shards = shard_dataset(&ds, 1, 1);
    assert!(PjrtSession::for_shard(reg, &shards[0], obj.as_ref()).is_err());
}
