//! The TCP process-cluster engine, end to end with **real spawned worker
//! processes on loopback**:
//!
//! * serial ≡ tcp trace parity, bit-exact modulo the wallclock and
//!   `wire_bytes` columns, through `run_experiment` on a fig2-style
//!   config (the acceptance pin for the wire refactor);
//! * collective-surface parity against `SerialCluster` outside a full
//!   run;
//! * measured `wire_bytes` accounting: zero on in-memory engines,
//!   positive and monotone on tcp;
//! * hang safety: a *wedged* (accepting but never replying) worker
//!   surfaces as `Err` within the socket timeout — at the algorithm
//!   level as an `AlgoError` — never a deadlock.
//!
//! Self-hosted clusters need the `dane` binary for their worker
//! children; tests run inside the test harness binary, so they point
//! the `set_worker_binary` override at the compiled CLI.

use dane::comm::wire::{self, Reply};
use dane::comm::ExecTopology;
use dane::config::{
    AlgoConfig, BackendKind, DatasetConfig, EngineKind, ExperimentConfig, FaultPolicy,
    LossKind, NetConfig,
};
use dane::coordinator::driver::run_experiment;
use dane::coordinator::tcp::TcpCluster;
use dane::coordinator::{dane as dane_algo, Cluster, RunCtx, SerialCluster};
use dane::data::synthetic_fig2;
use dane::loss::{Objective, Ridge};
use dane::metrics::Trace;
use std::io::Write;
use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

fn ensure_worker_bin() {
    // OnceLock-backed override: no env mutation, so Miri/TSan never see
    // a setenv/getenv race (concurrent setenv/getenv is UB on glibc).
    dane::coordinator::tcp::set_worker_binary(env!("CARGO_BIN_EXE_dane"));
}

fn fig2_cfg(engine: EngineKind) -> ExperimentConfig {
    ExperimentConfig {
        name: "tcp-parity".into(),
        dataset: DatasetConfig::Fig2 { n: 1024, d: 16, paper_reg: 0.005 },
        loss: LossKind::Ridge,
        lambda: 0.01,
        algo: AlgoConfig::Dane { eta: 1.0, mu_over_lambda: 1.0 },
        machines: 4,
        rounds: 12,
        tol: 1e-10,
        seed: 7,
        backend: BackendKind::Native,
        engine,
        workers: None,
        threads: None,
        topology: None,
        data_by_ref: false,
        eval_test: false,
        net: NetConfig::datacenter(),
        fault: FaultPolicy::FailFast,
        compression: dane::config::CompressionConfig::default(),
    }
}

/// Bit-exact row compare, modulo the two run-specific columns
/// (`elapsed_seconds` is wallclock, `wire_bytes` is transport-specific).
fn assert_rows_identical_mod_wire(a: &Trace, b: &Trace) {
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.round, rb.round);
        assert_eq!(ra.objective, rb.objective, "round {}", ra.round);
        assert_eq!(ra.suboptimality, rb.suboptimality, "round {}", ra.round);
        assert_eq!(ra.grad_norm, rb.grad_norm, "round {}", ra.round);
        assert_eq!(ra.test_loss, rb.test_loss, "round {}", ra.round);
        assert_eq!(ra.comm_rounds, rb.comm_rounds, "round {}", ra.round);
        assert_eq!(ra.comm_bytes, rb.comm_bytes, "round {}", ra.round);
        assert_eq!(
            ra.comm_modeled_seconds, rb.comm_modeled_seconds,
            "round {}",
            ra.round
        );
    }
}

#[test]
fn driver_serial_tcp_parity_on_fig2_config() {
    ensure_worker_bin();
    let serial = run_experiment(&fig2_cfg(EngineKind::Serial)).unwrap();
    let tcp = run_experiment(&fig2_cfg(EngineKind::Tcp)).unwrap();

    assert_eq!(serial.phi_star, tcp.phi_star);
    assert_eq!(serial.w, tcp.w, "final iterates must be bit-identical");
    assert_eq!(serial.converged, tcp.converged);
    assert_eq!(serial.rounds_to_tol, tcp.rounds_to_tol);
    assert_rows_identical_mod_wire(&serial.trace, &tcp.trace);

    // the wire column is the one legitimate difference: zero in memory,
    // positive and monotone over the socket
    assert!(serial.trace.rows.iter().all(|r| r.wire_bytes == 0));
    let wire: Vec<u64> = tcp.trace.rows.iter().map(|r| r.wire_bytes).collect();
    assert!(wire[0] > 0, "first tcp round moved no measured bytes");
    assert!(wire.windows(2).all(|w| w[0] <= w[1]), "wire_bytes not monotone: {wire:?}");
}

#[test]
fn collective_surface_matches_serial_bitwise() {
    ensure_worker_bin();
    let ds = synthetic_fig2(600, 10, 0.005, 13);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.02));
    let mut s = SerialCluster::new(&ds, obj, 4, 7);
    let mut t = TcpCluster::self_hosted(
        &ds,
        LossKind::Ridge,
        0.02,
        4,
        7,
        dane::comm::NetModel::free(),
        None,
        None,
        ExecTopology::Star,
    )
    .unwrap();
    assert_eq!(s.m(), t.m());
    assert_eq!(s.dim(), t.dim());

    let w = vec![0.05; 10];
    let (gs, ls) = s.grad_and_loss(&w).unwrap();
    let (gt, lt) = t.grad_and_loss(&w).unwrap();
    assert_eq!(gs, gt, "gradient must survive the wire bit-exactly");
    assert_eq!(ls, lt);
    assert_eq!(s.loss_only(&w).unwrap(), t.loss_only(&w).unwrap());
    assert_eq!(s.eval_loss(&w).unwrap(), t.eval_loss(&w).unwrap());

    let ds1 = s.dane_round(&w, &gs, 1.0, 0.01).unwrap();
    let dt1 = t.dane_round(&w, &gt, 1.0, 0.01).unwrap();
    assert_eq!(ds1, dt1, "DANE local-solve average must be bit-identical");

    let fs = s.dane_round_first(&w, &gs, 1.0, 0.01).unwrap();
    let ft = t.dane_round_first(&w, &gt, 1.0, 0.01).unwrap();
    assert_eq!(fs, ft);

    let (es, _) = s.local_erms(Some((0.5, 3))).unwrap();
    let (et, _) = t.local_erms(Some((0.5, 3))).unwrap();
    assert_eq!(es, et, "per-worker ERMs must be bit-identical");

    let targets: Vec<Vec<f64>> = (0..4).map(|k| vec![0.01 * k as f64; 10]).collect();
    assert_eq!(
        s.prox_all(&targets, 0.3).unwrap(),
        t.prox_all(&targets, 0.3).unwrap()
    );

    // modeled accounting identical; measured bytes only on the socket
    assert_eq!(s.comm_stats().rounds, t.comm_stats().rounds);
    assert_eq!(s.comm_stats().bytes, t.comm_stats().bytes);
    assert_eq!(s.comm_stats().wire_bytes, 0);
    assert!(t.comm_stats().wire_bytes > 0);

    // reset clears the measured counter with the modeled ones
    t.reset_comm();
    assert_eq!(t.comm_stats().wire_bytes, 0);
    assert_eq!(t.comm_stats().rounds, 0);
}

#[test]
fn full_dane_run_on_tcp_converges() {
    ensure_worker_bin();
    let ds = synthetic_fig2(1024, 12, 0.005, 7);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
    let (_, phi_star) =
        dane::solver::erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
    let mut cluster = TcpCluster::self_hosted(
        &ds,
        LossKind::Ridge,
        0.01,
        4,
        3,
        dane::comm::NetModel::free(),
        None,
        None,
        ExecTopology::Star,
    )
    .unwrap();
    let ctx = RunCtx::new(20).with_reference(phi_star).with_tol(1e-9);
    let res = dane_algo::run(&mut cluster, &Default::default(), &ctx).unwrap();
    assert!(res.converged, "{:?}", res.trace.suboptimality());
    let last = res.trace.rows.last().unwrap();
    assert_eq!(last.comm_rounds, 2 * last.round as u64 + 1);
    assert!(last.wire_bytes > 0);
}

/// A protocol-speaking stub worker that acks Init and then goes silent
/// forever (reads commands, never replies) — a wedged, not dead, worker.
fn spawn_wedged_worker() -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let (mut stream, _) = match listener.accept() {
            Ok(x) => x,
            Err(_) => return,
        };
        let mut body = Vec::new();
        // frame 1: Init — ack it so the cluster comes up
        if !matches!(wire::read_frame(&mut stream, &mut body), Ok(Some(_))) {
            return;
        }
        let mut enc = Vec::new();
        if wire::encode_reply(&Reply::Scalar(0.0), &mut enc).is_err()
            || stream.write_all(&enc).is_err()
        {
            return;
        }
        // then: swallow every further frame without ever answering,
        // until the leader hangs up
        while let Ok(Some(_)) = wire::read_frame(&mut stream, &mut body) {}
    });
    addr
}

#[test]
fn wedged_worker_times_out_instead_of_deadlocking() {
    ensure_worker_bin();
    let addr = spawn_wedged_worker();
    let ds = synthetic_fig2(128, 6, 0.005, 3);
    let mut cluster = TcpCluster::connect(
        &ds,
        LossKind::Ridge,
        0.01,
        &[addr.to_string()],
        3,
        dane::comm::NetModel::free(),
        None,
        Some(Duration::from_millis(300)),
        ExecTopology::Star,
    )
    .unwrap();

    let t0 = std::time::Instant::now();
    let err = cluster.grad_and_loss(&[0.0; 6]).unwrap_err();
    assert!(
        err.to_string().contains("wedged") || err.to_string().contains("timed out"),
        "unexpected cause: {err}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "timeout did not bound the wait"
    );

    // and through an algorithm: AlgoError, the CLI's error contract
    let out = dane_algo::run(&mut cluster, &Default::default(), &RunCtx::new(5));
    let algo_err = out.expect_err("wedged worker must fail the run");
    assert_eq!(algo_err.algo, "dane");
    assert!(
        algo_err.error.to_string().contains("timed out")
            || algo_err.error.to_string().contains("wedged"),
        "{}",
        algo_err.error
    );
}

#[test]
fn connect_to_nobody_fails_fast() {
    // A connect() to an address with no listener must be an Err, not a
    // hang or a panic. Bind-then-drop reserves a port that is closed.
    let port = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let ds = synthetic_fig2(64, 4, 0.005, 1);
    let res = TcpCluster::connect(
        &ds,
        LossKind::Ridge,
        0.01,
        &[format!("127.0.0.1:{port}")],
        1,
        dane::comm::NetModel::free(),
        None,
        Some(Duration::from_millis(500)),
        ExecTopology::Star,
    );
    assert!(res.is_err());
}

/// Deterministic LIBSVM file for the by-ref tests: every row carries a
/// handful of exact-decimal features so both load paths parse the same
/// tokens (values like 0.25/0.5 are exactly representable, so parity
/// failures mean a real data-plane bug, not float formatting).
fn write_libsvm_fixture(rows: usize, d: usize) -> (dane::util::tempdir::TempDir, String) {
    let dir = dane::util::tempdir::TempDir::new("tcp-byref").unwrap();
    let path = dir.path().join("fixture.svm");
    let mut body = String::from("# by-ref fixture\n");
    for i in 0..rows {
        let label = if i % 3 == 0 { "+1" } else { "-1" };
        let j1 = i % d + 1;
        let j2 = (i * 7 + 3) % d + 1;
        let v1 = 0.25 + (i % 8) as f64 * 0.125;
        let v2 = -0.5 + (i % 5) as f64 * 0.25;
        if j1 == j2 {
            body.push_str(&format!("{label} {j1}:{v1}\n"));
        } else if j1 < j2 {
            body.push_str(&format!("{label} {j1}:{v1} {j2}:{v2}\n"));
        } else {
            body.push_str(&format!("{label} {j2}:{v2} {j1}:{v1}\n"));
        }
    }
    std::fs::write(&path, body).unwrap();
    (dir, path.to_string_lossy().into_owned())
}

/// The by-reference acceptance pin: InitRef workers that stream their
/// own rows from disk run DANE **bit-identically** to by-value Init
/// workers fed the leader's shards — and bring-up costs O(m) bytes
/// instead of O(n*d).
#[test]
fn by_ref_init_matches_by_value_bitwise_and_ships_o_of_m_startup_bytes() {
    ensure_worker_bin();
    let (_dir, path) = write_libsvm_fixture(600, 24);
    let ds = dane::data::libsvm::load(std::path::Path::new(&path), 24).unwrap();
    let ctx = RunCtx::new(6).with_tol(0.0);

    let mut by_value = TcpCluster::self_hosted(
        &ds,
        LossKind::Ridge,
        0.02,
        4,
        11,
        dane::comm::NetModel::free(),
        None,
        None,
        ExecTopology::Star,
    )
    .unwrap();
    let value_res = dane_algo::run(&mut by_value, &Default::default(), &ctx).unwrap();
    let value_stats = by_value.comm_stats();
    drop(by_value);

    let mut by_ref = TcpCluster::self_hosted_by_ref(
        &ds,
        LossKind::Ridge,
        0.02,
        4,
        11,
        dane::comm::NetModel::free(),
        None,
        None,
        ExecTopology::Star,
        &path,
    )
    .unwrap();
    let ref_res = dane_algo::run(&mut by_ref, &Default::default(), &ctx).unwrap();
    let ref_stats = by_ref.comm_stats();

    assert_eq!(value_res.w, ref_res.w, "final iterates must be bit-identical");
    assert_rows_identical_mod_wire(&value_res.trace, &ref_res.trace);
    // steady-state measured traffic is identical too: InitRef changes
    // bring-up only, never the round plane
    for (rv, rr) in value_res.trace.rows.iter().zip(&ref_res.trace.rows) {
        assert_eq!(rv.wire_bytes, rr.wire_bytes, "round {}", rv.round);
    }

    // O(n*d) vs O(m): 600 rows of shard data by value vs 4 small
    // InitRef frames (+acks) by reference
    assert!(
        value_stats.startup_bytes > 10_000,
        "by-value startup {} should carry the whole dataset",
        value_stats.startup_bytes
    );
    assert!(
        ref_stats.startup_bytes < 2_048,
        "by-ref startup {} should be a handful of small frames",
        ref_stats.startup_bytes
    );
    assert!(ref_stats.startup_bytes > 0, "bring-up is measured, not free");

    // startup_bytes is a one-time cost: reset_comm clears the per-window
    // counters but keeps it
    by_ref.reset_comm();
    let after = by_ref.comm_stats();
    assert_eq!(after.wire_bytes, 0);
    assert_eq!(after.rounds, 0);
    assert_eq!(after.startup_bytes, ref_stats.startup_bytes);
}

/// A by-ref path that points at a missing file must surface as `Err`
/// from the constructor (the worker's InitRef reply), never a panic or
/// a hang.
#[test]
fn by_ref_init_with_a_missing_file_fails_fast() {
    ensure_worker_bin();
    let ds = synthetic_fig2(64, 4, 0.005, 1);
    let (_dir, path) = write_libsvm_fixture(4, 4);
    let missing = format!("{path}.does-not-exist");
    let res = TcpCluster::self_hosted_by_ref(
        &ds,
        LossKind::Ridge,
        0.01,
        2,
        1,
        dane::comm::NetModel::free(),
        None,
        Some(Duration::from_secs(5)),
        ExecTopology::Star,
        &missing,
    );
    let err = res.expect_err("missing by-ref file must fail bring-up");
    let msg = err.to_string();
    assert!(
        msg.contains("worker"),
        "error should attribute the failing worker: {msg}"
    );
}
