//! End-to-end DANE behavior across modules: Theorem-2 closed form,
//! Theorem-3 rate-vs-n, round accounting, driver plumbing and CSV
//! emission — the paper's core claims at integration level.

use dane::config::{
    AlgoConfig, BackendKind, DatasetConfig, EngineKind, ExperimentConfig, FaultPolicy,
    LossKind, NetConfig,
};
use dane::coordinator::dane as dane_algo;
use dane::coordinator::driver::run_experiment;
use dane::coordinator::{Cluster, RunCtx, SerialCluster};
use dane::data::synthetic_fig2;
use dane::linalg::{ops, CholeskyFactor, DenseMatrix};
use dane::loss::{Objective, Ridge};
use dane::metrics::emit;
use dane::solver::erm_solve;
use dane::util::tempdir::TempDir;
use std::sync::Arc;

/// Theorem 2: the DANE iterate on quadratics equals
/// w' - eta * (1/m) sum_i (H_i + mu I)^{-1} * grad phi(w').
#[test]
fn dane_iterate_matches_theorem2_closed_form() {
    let (n, d, m) = (240usize, 12usize, 4usize);
    let lam = 0.05;
    let mu = 0.02;
    let eta = 0.9;
    let ds = synthetic_fig2(n, d, lam / 2.0, 5);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
    let mut cluster = SerialCluster::new(&ds, obj.clone(), m, 9);

    let w_prev: Vec<f64> = (0..d).map(|i| 0.1 * i as f64 - 0.4).collect();
    let (g, _) = cluster.eval_grad_loss(&w_prev).unwrap();

    // dense closed form from the per-worker Hessians
    let mut step = vec![0.0; d];
    for wk in cluster.workers() {
        let hi = wk.dense_hessian(); // (1/n_i) X_i^T X_i + lam I
        let shifted = hi.add_diag(mu);
        let delta = CholeskyFactor::factor(&shifted).unwrap().solve(&g);
        ops::axpy(1.0 / m as f64, &delta, &mut step);
    }
    let mut expect = w_prev.clone();
    ops::axpy(-eta, &step, &mut expect);

    let got = cluster.dane_round(&w_prev, &g, eta, mu).unwrap();
    for j in 0..d {
        assert!(
            (got[j] - expect[j]).abs() < 1e-9,
            "{j}: {} vs {}",
            got[j],
            expect[j]
        );
    }
}

/// Theorem 2's contraction factor ||I - eta Htilde^{-1} H||_2 predicts the
/// measured per-round error contraction.
#[test]
fn contraction_factor_matches_operator_norm() {
    let (n, d, m) = (2000usize, 10usize, 4usize);
    let lam = 0.05;
    let ds = synthetic_fig2(n, d, lam / 2.0, 13);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
    let (w_hat, _) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
    let mut cluster = SerialCluster::new(&ds, obj.clone(), m, 7);

    // Build I - Htilde^{-1} H densely.
    let mut htilde_inv = DenseMatrix::zeros(d, d);
    for wk in cluster.workers() {
        let hi = wk.dense_hessian();
        let f = CholeskyFactor::factor(&hi).unwrap();
        for col in 0..d {
            let mut e = vec![0.0; d];
            e[col] = 1.0;
            let x = f.solve(&e);
            for row in 0..d {
                let v = htilde_inv.get(row, col) + x[row] / m as f64;
                htilde_inv.set(row, col, v);
            }
        }
    }
    // H: average of H_i weighted by n_i (equal shards here)
    let mut h = DenseMatrix::zeros(d, d);
    for wk in cluster.workers() {
        h.add_scaled(1.0 / m as f64, &wk.dense_hessian());
    }
    // M = I - Htilde^{-1} H
    let mut mmat = DenseMatrix::zeros(d, d);
    for col in 0..d {
        let mut hcol = vec![0.0; d];
        for row in 0..d {
            hcol[row] = h.get(row, col);
        }
        let mut prod = vec![0.0; d];
        htilde_inv.matvec(&hcol, &mut prod);
        for row in 0..d {
            let v = f64::from(row == col) - prod[row];
            mmat.set(row, col, v);
        }
    }
    // symmetric-ish; use power iteration on M^T M via fro upper bound
    let norm_bound = mmat.fro_norm(); // >= spectral norm

    // measured: error ratio over 5 rounds
    let mut w = vec![0.0; d];
    let mut prev_err = ops::dist2(&w, &w_hat);
    let mut worst_ratio: f64 = 0.0;
    for _ in 0..5 {
        let (g, _) = cluster.eval_grad_loss(&w).unwrap();
        w = cluster.dane_round(&w, &g, 1.0, 0.0).unwrap();
        let err = ops::dist2(&w, &w_hat);
        worst_ratio = worst_ratio.max(err / prev_err);
        prev_err = err;
    }
    assert!(
        worst_ratio <= norm_bound + 1e-9,
        "measured {worst_ratio} vs bound {norm_bound}"
    );
    assert!(worst_ratio < 1.0, "must contract: {worst_ratio}");
}

/// Theorem 3 at integration level: same m, 16x the data -> faster rate.
#[test]
fn rate_improves_with_total_samples() {
    let lam = 0.01;
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
    let mut rates = Vec::new();
    for &n in &[1024usize, 16384] {
        let ds = synthetic_fig2(n, 24, lam / 2.0, 3);
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut cluster = SerialCluster::new(&ds, obj.clone(), 8, 5);
        let ctx = RunCtx::new(20).with_reference(phi_star).with_tol(1e-13);
        let res = dane_algo::run(&mut cluster, &Default::default(), &ctx).unwrap();
        let f = res.trace.contraction_factors();
        let k = f.len().min(5);
        rates.push(f.iter().take(k).sum::<f64>() / k as f64);
    }
    assert!(rates[1] < 0.7 * rates[0], "rates {rates:?}");
}

#[test]
fn driver_runs_config_end_to_end_and_emits_csv() {
    let cfg = ExperimentConfig {
        name: "it-dane".into(),
        dataset: DatasetConfig::Fig2 { n: 1024, d: 16, paper_reg: 0.005 },
        loss: LossKind::Ridge,
        lambda: 0.01,
        algo: AlgoConfig::Dane { eta: 1.0, mu_over_lambda: 0.0 },
        machines: 4,
        rounds: 20,
        tol: 1e-8,
        seed: 3,
        backend: BackendKind::Native,
        engine: EngineKind::Serial,
        workers: None,
        threads: None,
        topology: None,
        data_by_ref: false,
        eval_test: false,
        net: NetConfig::datacenter(),
        fault: FaultPolicy::FailFast,
        compression: dane::config::CompressionConfig::default(),
    };
    let res = run_experiment(&cfg).unwrap();
    assert!(res.converged);
    assert!(res.rounds_to_tol.unwrap() <= 8);

    let dir = TempDir::new("it-dane").unwrap();
    let path = dir.path().join("trace.csv");
    emit::write_csv_file(&res.trace, &path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.lines().count() >= res.trace.len());
    // modeled network time must be monotone nondecreasing
    let mut prev = -1.0;
    for r in &res.trace.rows {
        assert!(r.comm_modeled_seconds >= prev);
        prev = r.comm_modeled_seconds;
    }
}

#[test]
fn mu_trades_stability_for_speed() {
    // Larger mu -> slower but monotone; mu = 0 fastest when shards are big.
    let lam = 0.01;
    let ds = synthetic_fig2(8192, 16, lam / 2.0, 23);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
    let mut rounds = Vec::new();
    for mu_mult in [0.0, 3.0, 30.0] {
        let mut cluster = SerialCluster::new(&ds, obj.clone(), 4, 5);
        let ctx = RunCtx::new(100).with_reference(phi_star).with_tol(1e-9);
        let opts = dane_algo::DaneOptions { eta: 1.0, mu: mu_mult * lam, ..Default::default() };
        let res = dane_algo::run(&mut cluster, &opts, &ctx).unwrap();
        rounds.push(res.trace.rounds_to_tol(1e-9).unwrap_or(usize::MAX));
    }
    assert!(rounds[0] <= rounds[1], "{rounds:?}");
    assert!(rounds[1] <= rounds[2], "{rounds:?}");
}
