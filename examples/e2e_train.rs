//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): the full system on a real
//! workload, proving all layers compose.
//!
//! 1. Generates the paper's synthetic ridge problem at paper scale
//!    (N = 65,536 x d = 500 — the largest fig. 2 configuration) plus a
//!    smooth-hinge classification workload, shards them over m = 16
//!    simulated machines, and trains with DANE, logging the full loss
//!    curve, gradient norms, and the communication bill under a
//!    datacenter network model.
//! 2. Re-runs a canonical-shard configuration on the **PJRT backend** —
//!    the AOT-compiled jax/Pallas artifacts — and checks it converges to
//!    the same optimum (native f64 vs artifact f32).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train
//! ```

use dane::comm::NetModel;
use dane::coordinator::dane as dane_algo;
use dane::coordinator::{Cluster, RunCtx, SerialCluster};
use dane::data::synthetic;
use dane::loss::{Objective, Ridge, SmoothHinge};
use dane::metrics::emit;
use dane::runtime::ArtifactRegistry;
use dane::solver::erm_solve;
use std::path::Path;
use std::sync::Arc;

fn main() -> Result<(), dane::Error> {
    let out = Path::new("results/e2e");
    std::fs::create_dir_all(out)?;

    // ---------------- Part 1a: ridge at paper scale -------------------
    let t0 = std::time::Instant::now();
    let paper_reg = 0.005;
    let (n_total, d, m) = (65_536, 500, 16);
    println!("[e2e] generating fig2 ridge: N={n_total} d={d} ...");
    let ds = dane::data::synthetic_fig2(n_total, d, paper_reg, 42);
    let lam = synthetic::fig2_lambda(paper_reg);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
    println!("[e2e] reference ERM solve ...");
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard())?;

    println!("[e2e] DANE, m={m}, datacenter net model ...");
    let mut cluster =
        SerialCluster::with_net(&ds, obj, m, 42, NetModel::datacenter());
    let ctx = RunCtx::new(30).with_reference(phi_star).with_tol(1e-10);
    let res = dane_algo::run(&mut cluster, &dane_algo::DaneOptions::default(), &ctx)?;
    emit::write_csv_file(&res.trace, &out.join("ridge_dane_m16.csv"))?;

    println!("[e2e] ridge loss curve (suboptimality by DANE iteration):");
    for r in &res.trace.rows {
        println!(
            "    round {:>2}  phi={:.9}  subopt={:.3e}  net={:.2}ms",
            r.round,
            r.objective,
            r.suboptimality.unwrap_or(f64::NAN),
            r.comm_modeled_seconds * 1e3
        );
    }
    let stats = cluster.comm_stats();
    println!(
        "[e2e] ridge: converged={} rounds={} bytes={} modeled_net={:.2}ms wall={:.1}s",
        res.converged,
        stats.rounds,
        stats.bytes,
        stats.modeled_seconds * 1e3,
        t0.elapsed().as_secs_f64()
    );
    assert!(res.converged, "e2e ridge run must converge");

    // ---------------- Part 1b: smooth hinge ---------------------------
    println!("\n[e2e] covtype-like smooth hinge: N=32768 d=54 m={m} ...");
    let lam_h = 1e-4;
    let dsh = dane::data::covtype_like(32_768, 4_096, 7);
    let objh: Arc<dyn Objective> = Arc::new(SmoothHinge::new(lam_h));
    let (_, phi_star_h) = erm_solve(objh.as_ref(), &dsh.as_single_shard())?;
    let test = dsh.test_shard().unwrap();
    let mut cluster = SerialCluster::with_net(&dsh, objh, m, 7, NetModel::datacenter());
    let ctx = RunCtx::new(30)
        .with_reference(phi_star_h)
        .with_tol(1e-8)
        .with_test_shard(test);
    let opts = dane_algo::DaneOptions { eta: 1.0, mu: 3.0 * lam_h, ..Default::default() };
    let resh = dane_algo::run(&mut cluster, &opts, &ctx)?;
    emit::write_csv_file(&resh.trace, &out.join("hinge_dane_m16.csv"))?;
    for r in resh.trace.rows.iter() {
        println!(
            "    round {:>2}  subopt={:.3e}  test_loss={:.6}",
            r.round,
            r.suboptimality.unwrap_or(f64::NAN),
            r.test_loss.unwrap_or(f64::NAN)
        );
    }
    assert!(resh.converged, "e2e hinge run must converge");

    // ---------------- Part 2: PJRT backend (optional) -----------------
    // The artifacts and the PJRT runtime are build-time optional; without
    // them this stage degrades to an explicit skip and the native stages
    // above remain the e2e proof. An artifacts/ tree that exists but
    // fails to open is a real regression and propagates as an error.
    println!("\n[e2e] PJRT backend (AOT jax/Pallas artifacts), canonical shard ...");
    let artifacts = Path::new("artifacts");
    match ArtifactRegistry::open(artifacts) {
        Err(e)
            if !artifacts.exists()
                || e.to_string().contains("PJRT runtime is unavailable") =>
        {
            println!("[e2e] skipping PJRT stage: {e}");
            println!("\n[e2e] native stages green; traces in results/e2e/");
        }
        Err(e) => return Err(e),
        Ok(registry) => {
            let ds2 = dane::data::synthetic_fig2(4_096, 500, paper_reg, 11); // pads to 2048x512 per shard
            let obj2: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
            let (_, phi_star2) = erm_solve(obj2.as_ref(), &ds2.as_single_shard())?;
            let mut pjrt_cluster = SerialCluster::new(&ds2, obj2, 2, 11);
            pjrt_cluster.use_pjrt(Arc::new(registry))?;
            let ctx2 = RunCtx::new(12).with_reference(phi_star2).with_tol(1e-5);
            let res2 =
                dane_algo::run(&mut pjrt_cluster, &dane_algo::DaneOptions::default(), &ctx2)?;
            emit::write_csv_file(&res2.trace, &out.join("ridge_dane_pjrt.csv"))?;
            for r in &res2.trace.rows {
                println!(
                    "    round {:>2}  subopt={:.3e}",
                    r.round,
                    r.suboptimality.unwrap_or(f64::NAN)
                );
            }
            println!("[e2e] pjrt converged={} (f32 artifact floor ~1e-6)", res2.converged);
            assert!(res2.converged, "e2e PJRT run must converge");
            println!("\n[e2e] all three stages green; traces in results/e2e/");
        }
    }
    Ok(())
}
