//! Fig. 2 in miniature: DANE vs ADMM on the synthetic ridge model,
//! showing the paper's headline phenomenon — DANE's convergence rate
//! *improves* as the total sample size grows, ADMM's does not.
//!
//! ```bash
//! cargo run --release --example ridge_synthetic
//! ```

use dane::coordinator::dane as dane_algo;
use dane::coordinator::{admm, RunCtx, SerialCluster};
use dane::data::synthetic;
use dane::loss::{Objective, Ridge};
use dane::solver::erm_solve;
use std::sync::Arc;

fn main() -> Result<(), dane::Error> {
    let d = 200;
    let m = 8;
    let paper_reg = 0.005;
    let lam = synthetic::fig2_lambda(paper_reg);

    println!("DANE vs ADMM, fig2 synthetic, d={d}, m={m}");
    println!(
        "{:>8} {:>8} {:>22} {:>22}",
        "N", "n/mach", "dane mean contraction", "admm mean contraction"
    );
    for &n_total in &[2_048usize, 8_192, 32_768] {
        let ds = dane::data::synthetic_fig2(n_total, d, paper_reg, 7);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard())?;
        let ctx = RunCtx::new(25).with_reference(phi_star).with_tol(1e-12);

        let mut c1 = SerialCluster::new(&ds, obj.clone(), m, 3);
        let r_dane = dane_algo::run(&mut c1, &dane_algo::DaneOptions::default(), &ctx)?;
        let mut c2 = SerialCluster::new(&ds, obj, m, 3);
        let r_admm = admm::run(&mut c2, &admm::AdmmOptions { rho: 0.05 }, &ctx)?;

        let rate = |t: &dane::metrics::Trace| {
            let f = t.contraction_factors();
            let k = f.len().min(6).max(1);
            f.iter().take(k).sum::<f64>() / k as f64
        };
        println!(
            "{:>8} {:>8} {:>22.4} {:>22.4}",
            n_total,
            n_total / m,
            rate(&r_dane.trace),
            rate(&r_admm.trace),
        );
    }
    println!("\n(contraction = per-iteration suboptimality ratio; lower is faster.");
    println!(" DANE's column should fall as N grows — Theorem 3; ADMM's should not.)");
    Ok(())
}
