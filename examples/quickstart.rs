//! Quickstart: DANE on the paper's synthetic ridge problem, through the
//! public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dane::coordinator::dane as dane_algo;
use dane::coordinator::{RunCtx, SerialCluster};
use dane::data::synthetic;
use dane::loss::{Objective, Ridge};
use dane::solver::erm_solve;
use std::sync::Arc;

fn main() -> Result<(), dane::Error> {
    // 1. Data: y = <x, w*> + noise, the exact fig. 2 generator.
    let paper_reg = 0.005;
    let ds = dane::data::synthetic_fig2(8_192, 200, paper_reg, 42);
    let lam = synthetic::fig2_lambda(paper_reg);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));

    // 2. Reference optimum, so we can report true suboptimality.
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard())?;

    // 3. A simulated cluster of 8 machines with a datacenter-like network.
    let mut cluster = SerialCluster::with_net(
        &ds,
        obj,
        8,
        42,
        dane::comm::NetModel::datacenter(),
    );

    // 4. Run DANE with the paper's preferred setting (eta = 1, mu = 0).
    let ctx = RunCtx::new(20).with_reference(phi_star).with_tol(1e-10);
    let res = dane_algo::run(&mut cluster, &dane_algo::DaneOptions::default(), &ctx)?;

    println!("DANE on fig2(N=8192, d=200), m=8:");
    println!(
        "{:>6} {:>14} {:>12} {:>10} {:>12}",
        "round", "suboptimality", "gradnorm", "commrnds", "modeled-net"
    );
    for r in &res.trace.rows {
        println!(
            "{:>6} {:>14.3e} {:>12.3e} {:>10} {:>10.2}us",
            r.round,
            r.suboptimality.unwrap_or(f64::NAN),
            r.grad_norm.unwrap_or(f64::NAN),
            r.comm_rounds,
            r.comm_modeled_seconds * 1e6,
        );
    }
    println!(
        "converged: {} (each DANE iteration = 2 communication rounds)",
        res.converged
    );
    Ok(())
}
