//! Smooth-hinge classification on the three fig. 3/4-style datasets:
//! train with DANE, report iterations-to-tolerance and test loss vs the
//! exact regularized minimizer ("Opt" in fig. 4).
//!
//! ```bash
//! cargo run --release --example hinge_classification
//! ```

use dane::coordinator::dane as dane_algo;
use dane::coordinator::{RunCtx, SerialCluster};
use dane::loss::{Objective, SmoothHinge};
use dane::solver::erm_solve;
use std::sync::Arc;

fn main() -> Result<(), dane::Error> {
    let m = 16;
    let cases: Vec<(dane::data::Dataset, f64)> = vec![
        (dane::data::covtype_like(8_192, 1_024, 11), 1e-5),
        (dane::data::astro_like(8_192, 1_024, 12), 5e-4),
        (dane::data::mnist47_like(4_096, 1_024, 13), 1e-3),
    ];

    for (ds, lam) in cases {
        let obj: Arc<dyn Objective> = Arc::new(SmoothHinge::new(lam));
        let (w_hat, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard())?;
        let test = ds.test_shard().expect("datasets carry test splits");
        let opt_test = {
            let mut rb = vec![0.0; test.n()];
            obj.value(&test, &w_hat, &mut rb)
        };

        let mut cluster = SerialCluster::new(&ds, obj.clone(), m, 5);
        let ctx = RunCtx::new(60)
            .with_reference(phi_star)
            .with_tol(1e-6)
            .with_test_shard(test.clone());
        let opts = dane_algo::DaneOptions { eta: 1.0, mu: 3.0 * lam, ..Default::default() };
        let res = dane_algo::run(&mut cluster, &opts, &ctx)?;

        let acc = {
            // 0/1 test accuracy of the trained predictor
            let mut correct = 0usize;
            for i in 0..test.n() {
                let pred = test.x.row_dot(i, &res.w);
                if pred * test.y[i] > 0.0 {
                    correct += 1;
                }
            }
            correct as f64 / test.n() as f64
        };

        println!("[{}] N={} d={} lam={lam:.0e} m={m}", ds.name, ds.n(), ds.d());
        println!(
            "  DANE(mu=3lam): rounds_to_1e-6={:?} converged={} final test loss={:.6} (opt {:.6}) acc={:.3}",
            res.trace.rounds_to_tol(1e-6),
            res.converged,
            res.trace.rows.last().and_then(|r| r.test_loss).unwrap_or(f64::NAN),
            opt_test,
            acc,
        );
    }
    Ok(())
}
