//! One-shot averaging's failure mode (paper §2 + Theorem 1).
//!
//! Part 1 reproduces the Theorem-1 construction numerically: OSA's error
//! plateaus in m (bias is not averaged away) while the pooled ERM keeps
//! improving. Part 2 shows the same effect on a ridge problem via the
//! actual coordinator: OSA (with and without bias correction) against
//! two iterations of DANE.
//!
//! ```bash
//! cargo run --release --example osa_bias
//! ```

use dane::coordinator::dane as dane_algo;
use dane::coordinator::{osa, RunCtx, SerialCluster};
use dane::data::{synthetic, thm1};
use dane::loss::{Objective, Ridge};
use dane::solver::erm_solve;
use std::sync::Arc;

fn main() -> Result<(), dane::Error> {
    // --- Part 1: the 1-d lower-bound construction --------------------
    let n = 100;
    let lam = 1.0 / (10.0 * (n as f64).sqrt());
    println!("Theorem-1 construction: f(w;z) = lam(w^2/2 + e^w) - zw, n={n}, lam={lam:.4}");
    println!("{:>4} {:>14} {:>14}", "m", "MSE(osa)", "MSE(pooled erm)");
    for &m in &[1usize, 4, 16, 64] {
        let e = thm1::estimate(lam, n, m, 300, 42);
        println!("{m:>4} {:>14.5} {:>14.5}", e.mse_osa, e.mse_erm);
    }
    println!("(OSA column plateaus: averaging cannot remove the per-machine bias.)\n");

    // --- Part 2: the same story through the coordinator --------------
    let paper_reg = 0.005;
    let ds = dane::data::synthetic_fig2(16_384, 100, paper_reg, 21);
    let rl = synthetic::fig2_lambda(paper_reg);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(rl));
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard())?;
    let m = 32;
    let ctx = RunCtx::new(3).with_reference(phi_star);

    let mut c = SerialCluster::new(&ds, obj.clone(), m, 3);
    let plain = osa::run(&mut c, &osa::OsaOptions::default(), &ctx)?;
    let mut c = SerialCluster::new(&ds, obj.clone(), m, 3);
    let bc = osa::run(
        &mut c,
        &osa::OsaOptions { bias_correction_r: Some(0.5), seed: 1 },
        &ctx,
    )?;
    let mut c = SerialCluster::new(&ds, obj, m, 3);
    let d2 = dane_algo::run(&mut c, &dane_algo::DaneOptions::default(), &ctx)?;

    println!("ridge fig2(N=16384, d=100), m={m}: empirical suboptimality");
    println!("  osa (1 round):        {:.3e}", plain.trace.last_suboptimality().unwrap());
    println!("  osa-bc (1 round):     {:.3e}", bc.trace.last_suboptimality().unwrap());
    println!("  dane (3 iterations):  {:.3e}", d2.trace.last_suboptimality().unwrap());
    println!("(multi-round communication buys orders of magnitude — fig. 4's message)");
    Ok(())
}
