//! Scaling study (paper §4.3, eq. 20): with lambda ~ 1/sqrt(N), DANE's
//! round count scales with the number of machines m but NOT with the
//! total sample size N — unlike gradient methods, whose round count
//! grows with the condition number and hence with N.
//!
//! Also prints the alpha-beta network model's view of each algorithm's
//! communication bill.
//!
//! ```bash
//! cargo run --release --example scaling
//! ```

use dane::comm::NetModel;
use dane::coordinator::dane as dane_algo;
use dane::coordinator::{gd, Cluster, RunCtx, SerialCluster};
use dane::loss::{Objective, Ridge};
use dane::solver::erm_solve;
use std::sync::Arc;

fn run_case(n_total: usize, m: usize, d: usize) -> Result<(usize, usize, f64), dane::Error> {
    // lambda = 1/sqrt(N): the regularized-ERM regime of §4.3
    let lam = 1.0 / (n_total as f64).sqrt();
    let ds = dane::data::synthetic_fig2(n_total, d, lam / 2.0, 9);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard())?;

    let tol = 1e-6;
    let ctx = RunCtx::new(200).with_reference(phi_star).with_tol(tol);
    let mut c = SerialCluster::with_net(&ds, obj.clone(), m, 3, NetModel::datacenter());
    let r_dane = dane_algo::run(&mut c, &dane_algo::DaneOptions::default(), &ctx)?;
    let modeled = c.comm_stats().modeled_seconds;

    let ctx = RunCtx::new(4000).with_reference(phi_star).with_tol(tol);
    let mut c = SerialCluster::new(&ds, obj, m, 3);
    let r_agd = gd::run_agd(&mut c, &gd::AgdOptions::default(), &ctx)?;

    Ok((
        r_dane.trace.rounds_to_tol(tol).unwrap_or(usize::MAX),
        r_agd.trace.rounds_to_tol(tol).unwrap_or(usize::MAX),
        modeled,
    ))
}

fn main() -> Result<(), dane::Error> {
    let d = 100;
    println!("lambda = 1/sqrt(N) regime (paper §4.3) — iterations to 1e-6");
    println!(
        "{:>8} {:>4} {:>6} {:>12} {:>12} {:>16}",
        "N", "m", "n/m", "dane iters", "agd iters", "dane net (ms)"
    );

    // N grows at fixed m: DANE flat-ish, AGD grows (condition number grows).
    for &n_total in &[4_096usize, 16_384, 65_536] {
        let (dn, ag, net) = run_case(n_total, 8, d)?;
        println!(
            "{:>8} {:>4} {:>6} {:>12} {:>12} {:>16.3}",
            n_total,
            8,
            n_total / 8,
            fmt(dn),
            fmt(ag),
            net * 1e3
        );
    }
    println!();
    // m grows at fixed n-per-machine: DANE grows ~linearly in m (eq. 20).
    for &m in &[4usize, 16, 64] {
        let n_total = 1_024 * m;
        let (dn, ag, net) = run_case(n_total, m, d)?;
        println!(
            "{:>8} {:>4} {:>6} {:>12} {:>12} {:>16.3}",
            n_total,
            m,
            1_024,
            fmt(dn),
            fmt(ag),
            net * 1e3
        );
    }
    println!("\n(top block: N x16 at fixed m -> DANE's column ~flat, AGD's grows;");
    println!(" bottom block: fixed n per machine -> both grow with m, DANE mildly.)");
    Ok(())
}

fn fmt(v: usize) -> String {
    if v == usize::MAX {
        "*".to_string()
    } else {
        v.to_string()
    }
}
