"""Render the paper's figures from the harness CSVs in results/.

Usage (after `make figures` or the `dane fig*` subcommands):

    python python/plot.py --results results --out results/plots

Produces fig2.png (convergence grids), fig4_<dataset>.png (test-loss
curves) — matplotlib renderings of exactly the series the paper plots.
Fig. 3 is a table; `dane fig3` already prints it and writes CSV.
"""

import argparse
import csv
import pathlib
import re

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402


def read_trace(path):
    rows = []
    with open(path) as f:
        for row in csv.DictReader(f):
            rows.append(row)
    return rows


def fig2(results: pathlib.Path, out: pathlib.Path):
    fdir = results / "fig2"
    if not fdir.exists():
        print("skip fig2 (no results/fig2)")
        return
    pat = re.compile(r"(dane|admm)_m(\d+)_N(\d+)\.csv")
    cells = {}
    for p in fdir.iterdir():
        m = pat.match(p.name)
        if m:
            cells[(m.group(1), int(m.group(2)), int(m.group(3)))] = read_trace(p)
    ns = sorted({k[2] for k in cells})
    ms = sorted({k[1] for k in cells})
    fig, axes = plt.subplots(2, len(ns), figsize=(4 * len(ns), 7), sharex=True)
    for col, n in enumerate(ns):
        for row, algo in enumerate(["dane", "admm"]):
            ax = axes[row][col] if len(ns) > 1 else axes[row]
            for m in ms:
                trace = cells.get((algo, m, n))
                if not trace:
                    continue
                xs, ys = [], []
                for r in trace:
                    if r["suboptimality"]:
                        v = float(r["suboptimality"])
                        if v > 0:
                            xs.append(int(r["round"]))
                            ys.append(v)
                ax.semilogy(xs, ys, marker="o", ms=3, label=f"m={m}")
            ax.set_title(f"{algo.upper()}, N={n}")
            ax.grid(alpha=0.3)
            if row == 1:
                ax.set_xlabel("iteration")
            if col == 0:
                ax.set_ylabel("suboptimality")
    axes[0][0].legend()
    fig.suptitle("Fig. 2: DANE (top) vs ADMM (bottom) on synthetic ridge")
    fig.tight_layout()
    fig.savefig(out / "fig2.png", dpi=120)
    print(f"wrote {out/'fig2.png'}")


def fig4(results: pathlib.Path, out: pathlib.Path):
    fdir = results / "fig4"
    if not fdir.exists():
        print("skip fig4 (no results/fig4)")
        return
    datasets = sorted({p.name.rsplit("_", 1)[0] for p in fdir.glob("*.csv")})
    for ds in datasets:
        fig, ax = plt.subplots(figsize=(6, 4))
        for algo in ["dane", "admm", "osa"]:
            p = fdir / f"{ds}_{algo}.csv"
            if not p.exists():
                continue
            trace = read_trace(p)
            xs = [int(r["round"]) for r in trace if r["test_loss"]]
            ys = [float(r["test_loss"]) for r in trace if r["test_loss"]]
            style = dict(marker="o", ms=3) if algo != "osa" else dict(
                marker="s", ms=5, linestyle="--"
            )
            ax.plot(xs, ys, label=algo.upper(), **style)
        ax.set_xlabel("iteration")
        ax.set_ylabel("test regularized loss")
        ax.set_title(f"Fig. 4: {ds} (m = 64)")
        ax.grid(alpha=0.3)
        ax.legend()
        fig.tight_layout()
        fig.savefig(out / f"fig4_{ds}.png", dpi=120)
        print(f"wrote {out/f'fig4_{ds}.png'}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default="results")
    ap.add_argument("--out", default="results/plots")
    args = ap.parse_args()
    results = pathlib.Path(args.results)
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    fig2(results, out)
    fig4(results, out)


if __name__ == "__main__":
    main()
