"""L2 correctness: the jax compute graphs in model.py.

Validated against straight numpy implementations (independent of the L1
kernels), matching rust/src/loss definition-for-definition.
"""

import numpy as np
import pytest

from compile import model

LAM = np.float32(0.01)


def case(seed, n=256, d=24, classification=False):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    if classification:
        y = rng.choice([-1.0, 1.0], n).astype(np.float32)
    else:
        y = rng.standard_normal(n).astype(np.float32)
    w = rng.standard_normal(d).astype(np.float32)
    return x, y, w, np.float32(1.0 / n)


def sh(a):
    return np.where(a >= 1, 0.0, np.where(a <= 0, 1 - a - 0.5, (1 - a) ** 2 / 2))


def shd(a):
    return np.where(a >= 1, 0.0, np.where(a <= 0, -1.0, -(1 - a)))


class TestRidgeGrad:
    def test_matches_numpy(self):
        x, y, w, ninv = case(0)
        g, loss = model.ridge_grad_jit(x, y, w, LAM, ninv)
        n = x.shape[0]
        g_np = x.T @ (x @ w - y) / n + LAM * w
        l_np = ((x @ w - y) ** 2).sum() / (2 * n) + 0.5 * LAM * (w @ w)
        np.testing.assert_allclose(np.asarray(g), g_np, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(float(loss), l_np, rtol=2e-4)

    def test_gradient_of_loss(self):
        # finite differences on the returned loss
        x, y, w, ninv = case(1, n=256, d=8)
        g, _ = model.ridge_grad_jit(x, y, w, LAM, ninv)
        eps = 1e-2  # f32: balance truncation vs rounding
        for j in range(8):
            wp, wm = w.copy(), w.copy()
            wp[j] += eps
            wm[j] -= eps
            _, lp = model.ridge_grad_jit(x, y, wp, LAM, ninv)
            _, lm = model.ridge_grad_jit(x, y, wm, LAM, ninv)
            fd = (float(lp) - float(lm)) / (2 * eps)
            assert abs(fd - float(g[j])) < 5e-2, (j, fd, float(g[j]))


class TestRidgeLocalSolve:
    def test_one_step_newton_when_single_machine(self):
        """m=1, eta=1, mu=0: the DANE step lands on the exact ridge
        minimizer (paper: 'converges in a single iteration')."""
        x, y, w, ninv = case(2, n=256, d=16)
        n, d = x.shape
        g, _ = model.ridge_grad_jit(x, y, w, LAM, ninv)
        w1 = model.ridge_local_solve_jit(
            x, w, g, np.float32(1.0), np.float32(0.0), LAM, ninv
        )
        h = x.T @ x / n + LAM * np.eye(d, dtype=np.float32)
        w_star = np.linalg.solve(h, x.T @ y / n)
        np.testing.assert_allclose(np.asarray(w1), w_star, rtol=1e-3, atol=1e-3)

    def test_mu_shrinks_the_step(self):
        x, y, w, ninv = case(3)
        g, _ = model.ridge_grad_jit(x, y, w, LAM, ninv)
        w_small = model.ridge_local_solve_jit(
            x, w, g, np.float32(1.0), np.float32(0.0), LAM, ninv
        )
        w_big_mu = model.ridge_local_solve_jit(
            x, w, g, np.float32(1.0), np.float32(100.0), LAM, ninv
        )
        step_small = np.linalg.norm(np.asarray(w_small) - w)
        step_big = np.linalg.norm(np.asarray(w_big_mu) - w)
        assert step_big < step_small / 5

    def test_eta_scales_linearly(self):
        x, y, w, ninv = case(4)
        g, _ = model.ridge_grad_jit(x, y, w, LAM, ninv)
        w_full = model.ridge_local_solve_jit(
            x, w, g, np.float32(1.0), np.float32(0.0), LAM, ninv
        )
        w_half = model.ridge_local_solve_jit(
            x, w, g, np.float32(0.5), np.float32(0.0), LAM, ninv
        )
        np.testing.assert_allclose(
            np.asarray(w_half) - w,
            0.5 * (np.asarray(w_full) - w),
            rtol=1e-3,
            atol=1e-5,
        )


class TestHinge:
    def test_grad_loss_matches_numpy(self):
        x, y, w, ninv = case(5, classification=True)
        g, loss = model.hinge_grad_loss_jit(x, y, w, LAM, ninv)
        n = x.shape[0]
        m = y * (x @ w)
        g_np = x.T @ (shd(m) * y) / n + LAM * w
        l_np = sh(m).mean() + 0.5 * LAM * (w @ w)
        np.testing.assert_allclose(np.asarray(g), g_np, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(float(loss), l_np, rtol=2e-4)

    def test_local_solve_reaches_stationarity_m1(self):
        """m=1, eta=1, mu=0: local solve minimizes phi itself."""
        x, y, w, ninv = case(6, classification=True, d=12)
        g0, _ = model.hinge_grad_loss_jit(x, y, w, LAM, ninv)
        w1 = model.hinge_local_solve_jit(
            x, y, w, g0, np.float32(1.0), np.float32(0.0), LAM, ninv
        )
        g_at, _ = model.hinge_grad_loss_jit(x, y, np.asarray(w1), LAM, ninv)
        assert float(np.linalg.norm(np.asarray(g_at))) < 1e-5

    def test_local_solve_decreases_local_objective(self):
        x, y, w, ninv = case(7, classification=True)
        g, _ = model.hinge_grad_loss_jit(x, y, w, LAM, ninv)
        mu = np.float32(0.03)
        w1 = np.asarray(
            model.hinge_local_solve_jit(x, y, w, g, np.float32(1.0), mu, LAM, ninv)
        )

        def h(v):
            gp, _ = model.hinge_grad_loss_jit(x, y, w, LAM, ninv)
            c = np.asarray(gp) - np.asarray(g)
            _, lv = model.hinge_grad_loss_jit(x, y, v, LAM, ninv)
            return float(lv) - c @ v + 0.5 * float(mu) * np.sum((v - w) ** 2)

        assert h(w1) < h(w) - 1e-7

    def test_padding_rows_ignored(self):
        x, y, w, ninv = case(8, classification=True, n=256)
        x2 = np.vstack([x, np.zeros((256, x.shape[1]), np.float32)])
        y2 = np.concatenate([y, np.zeros(256, np.float32)])
        g1, l1 = model.hinge_grad_loss_jit(x, y, w, LAM, ninv)
        g2, l2 = model.hinge_grad_loss_jit(x2, y2, w, LAM, ninv)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)
        np.testing.assert_allclose(float(l1), float(l2), atol=1e-6)


class TestCg:
    def test_cg_solves_spd_system(self):
        rng = np.random.default_rng(9)
        d = 20
        a = rng.standard_normal((d, d)).astype(np.float32)
        spd = a.T @ a + 0.5 * np.eye(d, dtype=np.float32)
        b = rng.standard_normal(d).astype(np.float32)
        import jax.numpy as jnp

        x = model._cg(lambda v: jnp.asarray(spd) @ v, jnp.asarray(b))
        np.testing.assert_allclose(
            spd @ np.asarray(x), b, rtol=1e-3, atol=1e-3
        )

    def test_cg_zero_rhs(self):
        import jax.numpy as jnp

        x = model._cg(lambda v: v, jnp.zeros(5, jnp.float32))
        assert float(np.abs(np.asarray(x)).max()) == 0.0
