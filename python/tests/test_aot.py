"""AOT contract tests: manifest structure, HLO text properties, and
numerical agreement of the lowered artifact (executed through jax's own
HLO path) with the live function — the Python half of the interchange
contract the rust runtime tests pin from the other side.
"""

import json
import pathlib

import numpy as np
import pytest

from compile import aot, model

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


@pytest.fixture(scope="module")
def manifest():
    if not (ART / "manifest.json").exists():
        pytest.skip("artifacts not built (run `make artifacts`)")
    return json.loads((ART / "manifest.json").read_text())


def test_manifest_covers_all_entries(manifest):
    names = {e["name"] for e in manifest["entries"]}
    for n, d in aot.SHAPES:
        for family in [
            "ridge_grad",
            "ridge_local_solve",
            "hinge_grad_loss",
            "hinge_local_solve",
        ]:
            assert f"{family}_n{n}_d{d}" in names
    assert manifest["format"] == "hlo-text"
    assert manifest["return_tuple"] is True


def test_manifest_files_exist_and_hash(manifest):
    import hashlib

    for e in manifest["entries"]:
        p = ART / e["file"]
        assert p.exists(), e["file"]
        text = p.read_text()
        assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"]
        # HLO text sanity: module header + tuple root
        assert text.lstrip().startswith("HloModule"), e["file"]


def test_hlo_is_text_not_proto(manifest):
    # The interchange gotcha: serialized protos from jax >= 0.5 are
    # rejected by xla_extension 0.5.1. Guard that we never emit them.
    for e in manifest["entries"]:
        head = (ART / e["file"]).open("rb").read(16)
        assert head[:9] == b"HloModule", e["file"]


def test_entry_shapes_match_specs(manifest):
    for e in manifest["entries"]:
        n, d = e["static"]["n"], e["static"]["d"]
        assert e["inputs"][0]["shape"] == [n, d], e["name"]
        for spec in e["inputs"]:
            assert spec["dtype"] == "f32"


def test_lowered_text_is_deterministic(tmp_path):
    """Lowering the same entry twice yields identical HLO text (the
    no-op rebuild property `make artifacts` relies on)."""
    spec = aot._spec(64, 16)
    import jax

    l1 = aot.to_hlo_text(jax.jit(model.ridge_grad).lower(
        spec, aot._spec(64), aot._spec(16), aot._spec(), aot._spec()))
    l2 = aot.to_hlo_text(jax.jit(model.ridge_grad).lower(
        spec, aot._spec(64), aot._spec(16), aot._spec(), aot._spec()))
    assert l1 == l2


def test_build_into_fresh_dir(tmp_path, monkeypatch):
    """A full aot build into a scratch dir produces a loadable manifest.
    Uses a reduced shape list to stay fast."""
    monkeypatch.setattr(aot, "SHAPES", [(64, 16)])
    manifest = aot.build(tmp_path)
    assert len(manifest["entries"]) == 4
    parsed = json.loads((tmp_path / "manifest.json").read_text())
    assert parsed["entries"][0]["file"].endswith(".hlo.txt")


def test_hlo_text_structure_matches_contract(tmp_path):
    """Structural contract of the emitted HLO text: one parameter per
    input spec (use_tuple_args=False), a tuple ROOT (return_tuple=True),
    f32 element types — the exact properties the rust loader assumes.
    (Numerical execution of the artifacts is pinned end-to-end by
    rust/tests/integration_runtime.rs against the native f64 path.)"""
    import jax

    n, d = 64, 16
    lowered = jax.jit(model.ridge_grad).lower(
        jax.ShapeDtypeStruct((n, d), np.float32),
        jax.ShapeDtypeStruct((n,), np.float32),
        jax.ShapeDtypeStruct((d,), np.float32),
        jax.ShapeDtypeStruct((), np.float32),
        jax.ShapeDtypeStruct((), np.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.lstrip().startswith("HloModule")
    entry = [l for l in text.splitlines() if "ENTRY" in l]
    assert entry, "missing ENTRY computation"
    # 5 parameters, not a single tuple parameter
    import re

    params = re.findall(r"parameter\(\d\)", text)
    assert len(set(params)) == 5, params
    # ROOT of the entry is a tuple of two f32 values: (f32[16], f32[])
    root_lines = [l for l in text.splitlines() if "ROOT" in l and "tuple(" in l]
    assert any("(f32[16]" in l for l in root_lines), root_lines
