"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, dtypes, block sizes, padding fractions and value
scales; every property asserts allclose against the oracle. This is the
CORE correctness signal for the kernel layer — the rust-side integration
tests only check the already-lowered artifacts.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gram_matvec, hinge_grad, resid_matvec
from compile.kernels.gram_matvec import resid_matvec_ss
from compile.kernels import ref

# interpret-mode pallas is slow; keep cases small but varied.
SETTINGS = dict(max_examples=25, deadline=None)

dims = st.sampled_from([1, 3, 8, 17, 32, 64])
block_multiples = st.sampled_from([1, 2, 4])
block_rows = st.sampled_from([8, 32, 128])
# jax runs with x64 disabled (the AOT artifacts are f32 by contract);
# float64 inputs would be silently downcast, so only f32 is meaningful.
dtypes = st.sampled_from([jnp.float32])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def tol(dtype):
    return dict(rtol=2e-3, atol=2e-3)


def make_case(seed, n, d, dtype, classification=False, pad_rows=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(dtype)
    v = rng.standard_normal(d).astype(dtype)
    dvec = rng.random(n).astype(dtype)
    r = rng.standard_normal(n).astype(dtype)
    if classification:
        y = rng.choice([-1.0, 1.0], n).astype(dtype)
    else:
        y = rng.standard_normal(n).astype(dtype)
    if pad_rows:
        x[-pad_rows:] = 0.0
        y[-pad_rows:] = 0.0
        dvec[-pad_rows:] = 0.0
        r[-pad_rows:] = 0.0
    return x, y, v, dvec, r


@settings(**SETTINGS)
@given(seed=seeds, bm=block_rows, mult=block_multiples, d=dims, dtype=dtypes)
def test_gram_matvec_matches_oracle(seed, bm, mult, d, dtype):
    n = bm * mult
    x, _, v, dvec, _ = make_case(seed, n, d, dtype)
    out = gram_matvec(x, dvec, v, block_rows=bm)
    expect = ref.gram_matvec_ref(x, dvec, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), **tol(dtype))


@settings(**SETTINGS)
@given(seed=seeds, bm=block_rows, mult=block_multiples, d=dims, dtype=dtypes)
def test_resid_matvec_matches_oracle(seed, bm, mult, d, dtype):
    n = bm * mult
    x, _, v, dvec, r = make_case(seed, n, d, dtype)
    out = resid_matvec(x, dvec, v, r, block_rows=bm)
    expect = ref.resid_matvec_ref(x, dvec, v, r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), **tol(dtype))


@settings(**SETTINGS)
@given(seed=seeds, bm=block_rows, mult=block_multiples, d=dims, dtype=dtypes)
def test_resid_matvec_ss_sum_of_squares(seed, bm, mult, d, dtype):
    n = bm * mult
    x, _, v, dvec, r = make_case(seed, n, d, dtype)
    _, ss = resid_matvec_ss(x, dvec, v, r, block_rows=bm)
    t = np.asarray(x) @ np.asarray(v) - np.asarray(r)
    expect = float(np.sum(np.asarray(dvec) * t * t))
    np.testing.assert_allclose(float(ss[0]), expect, **tol(dtype))


@settings(**SETTINGS)
@given(seed=seeds, bm=block_rows, mult=block_multiples, d=dims, dtype=dtypes)
def test_hinge_grad_matches_oracle(seed, bm, mult, d, dtype):
    n = bm * mult
    x, y, _, _, _ = make_case(seed, n, d, dtype, classification=True)
    rng = np.random.default_rng(seed + 1)
    w = rng.standard_normal(d).astype(dtype)
    g, loss = hinge_grad(x, y, w, block_rows=bm)
    ge, le = ref.hinge_grad_ref(x, y, w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ge), **tol(dtype))
    np.testing.assert_allclose(float(loss[0]), float(le), **tol(dtype))


@settings(**SETTINGS)
@given(seed=seeds, pad=st.integers(min_value=1, max_value=31), dtype=dtypes)
def test_padding_rows_are_inert(seed, pad, dtype):
    """Zero rows with y = 0 must contribute nothing (the PJRT padding
    contract)."""
    n, d = 64, 16
    x, y, _, _, _ = make_case(seed, n, d, dtype, classification=True, pad_rows=pad)
    rng = np.random.default_rng(seed + 2)
    w = rng.standard_normal(d).astype(dtype)
    g_pad, l_pad = hinge_grad(x, y, w, block_rows=32)
    g_ref, l_ref = ref.hinge_grad_ref(x[:-pad], y[:-pad], w)
    np.testing.assert_allclose(np.asarray(g_pad), np.asarray(g_ref), **tol(dtype))
    np.testing.assert_allclose(float(l_pad[0]), float(l_ref), **tol(dtype))


def test_block_rows_must_divide_n():
    x = jnp.zeros((100, 8), jnp.float32)
    v = jnp.zeros(8, jnp.float32)
    ones = jnp.ones(100, jnp.float32)
    with pytest.raises(ValueError):
        gram_matvec(x, ones, v, block_rows=64)


def test_gram_matvec_is_spd_quadratic_form():
    """v^T (X^T X v) >= 0 for all v — the kernel must preserve SPD-ness
    or CG in the rust twin would break."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((128, 24)).astype(np.float32)
    ones = np.ones(128, np.float32)
    for _ in range(10):
        v = rng.standard_normal(24).astype(np.float32)
        out = gram_matvec(x, ones, v, block_rows=32)
        assert float(np.asarray(out) @ v) >= -1e-3


def test_smooth_hinge_piecewise_identities():
    a = jnp.asarray([-5.0, 0.0, 0.25, 0.5, 0.999, 1.0, 3.0], jnp.float32)
    l = np.asarray(ref.smooth_hinge(a))
    d = np.asarray(ref.smooth_hinge_d(a))
    dd = np.asarray(ref.smooth_hinge_dd(a))
    # value continuity at knots
    np.testing.assert_allclose(l[1], 0.5)
    np.testing.assert_allclose(l[5], 0.0)
    # derivative signs and ranges
    assert np.all(d <= 0.0)
    assert np.all(d >= -1.0)
    # curvature only inside (0, 1)
    np.testing.assert_allclose(dd, [0, 0, 1, 1, 1, 0, 0])
