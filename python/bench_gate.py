#!/usr/bin/env python3
"""Perf-regression gate over the ``dane-bench-v1`` trajectory files.

Usage::

    bench_gate.py COMMITTED REGENERATED [THRESHOLD]
    bench_gate.py --self-test

Compares every benchmark entry's ``median_ns`` in REGENERATED against
the same-named entry in the COMMITTED baseline and exits nonzero when
any entry regresses by more than THRESHOLD (default 1.5x).

Two deliberate carve-outs:

* A committed file whose ``label`` starts with ``unmeasured-estimate``
  holds authored analytic placeholders, not measurements (the authoring
  container has no toolchain to run on — see rust/benches/README.md).
  Such a baseline is skipped with a notice instead of compared; the
  gate arms itself the first time a *measured* baseline is committed,
  without a workflow change.

* A **zero-valued baseline** is a contract, not a measurement — the
  ``leader allocs/round ... star ...`` entries from roundpath_micro
  record the allocation-free round path as 0.0.  Any nonzero
  regenerated value fails outright: a reintroduced per-round
  allocation turns CI red even though it is orders of magnitude too
  small to move a latency median.

Entries present on only one side are ignored here — the workflow's
separate key-set diff step owns rename/drop drift, and mixing the two
concerns would double-report every rename as a "regression".
"""

import json
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "dane-bench-v1":
        raise SystemExit(f"{path}: not a dane-bench-v1 file")
    return doc


def compare(committed, regenerated, threshold=1.5):
    """Return (skipped, failures, lines) for two parsed trajectory docs.

    ``failures`` is a list of (name, baseline, new) tuples; ``lines``
    is the human-readable report.
    """
    label = committed.get("label", "")
    if label.startswith("unmeasured-estimate"):
        return True, [], [
            "baseline is an authored estimate (label 'unmeasured-estimate"
            "...'); skipping median comparison"
        ]
    base = {r["name"]: r["median_ns"] for r in committed["results"]}
    new = {r["name"]: r["median_ns"] for r in regenerated["results"]}
    failures = []
    lines = []
    for name in sorted(base):
        if name not in new:
            continue  # key-set diff step owns missing entries
        b, n = base[name], new[name]
        if b == 0.0:
            ok = n == 0.0
            verdict = "OK" if ok else f"FAIL (zero baseline, got {n})"
            lines.append(f"  {name}: contract 0.0 -> {n}  {verdict}")
        else:
            ratio = n / b
            ok = ratio <= threshold
            verdict = "OK" if ok else f"FAIL (> {threshold}x)"
            lines.append(f"  {name}: {b:.1f} -> {n:.1f}  ({ratio:.2f}x)  {verdict}")
        if not ok:
            failures.append((name, b, n))
    return False, failures, lines


def self_test():
    baseline = {
        "schema": "dane-bench-v1",
        "label": "v1.0",
        "results": [
            {"name": "round", "median_ns": 100.0},
            {"name": "allocs star", "median_ns": 0.0},
            {"name": "renamed-away", "median_ns": 5.0},
        ],
    }

    # within threshold + zero contract held -> pass
    ok_run = {
        "schema": "dane-bench-v1",
        "label": "ci",
        "results": [
            {"name": "round", "median_ns": 140.0},
            {"name": "allocs star", "median_ns": 0.0},
        ],
    }
    skipped, failures, _ = compare(baseline, ok_run)
    assert not skipped and failures == [], failures

    # 2x latency regression -> fail
    slow_run = {"schema": "dane-bench-v1", "results": [
        {"name": "round", "median_ns": 200.0},
        {"name": "allocs star", "median_ns": 0.0},
    ]}
    _, failures, _ = compare(baseline, slow_run)
    assert [f[0] for f in failures] == ["round"], failures

    # any allocation against the zero contract -> fail
    alloc_run = {"schema": "dane-bench-v1", "results": [
        {"name": "round", "median_ns": 100.0},
        {"name": "allocs star", "median_ns": 1.0},
    ]}
    _, failures, _ = compare(baseline, alloc_run)
    assert [f[0] for f in failures] == ["allocs star"], failures

    # authored-estimate baseline -> skipped, never fails
    estimate = dict(baseline, label="unmeasured-estimate: authored")
    skipped, failures, _ = compare(estimate, slow_run)
    assert skipped and failures == []

    # missing entries are the key-set step's problem, not ours
    _, failures, _ = compare(baseline, ok_run)
    assert all(f[0] != "renamed-away" for f in failures)

    print("bench_gate self-test OK")


def main(argv):
    if argv[1:] == ["--self-test"]:
        self_test()
        return 0
    if len(argv) not in (3, 4):
        print(__doc__)
        return 2
    threshold = float(argv[3]) if len(argv) == 4 else 1.5
    committed, regenerated = load(argv[1]), load(argv[2])
    skipped, failures, lines = compare(committed, regenerated, threshold)
    print(f"bench gate: {argv[1]} vs {argv[2]} (threshold {threshold}x)")
    for line in lines:
        print(line)
    if skipped:
        return 0
    if failures:
        print(f"bench gate: {len(failures)} regression(s)")
        return 1
    print("bench gate: no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
