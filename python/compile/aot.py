"""AOT driver: lower every L2 entry point to HLO *text* + a manifest.

This is the single place Python runs in the whole system — once, at build
time (`make artifacts`). The rust coordinator loads the emitted
artifacts/*.hlo.txt through the xla crate's PJRT CPU client and never
touches Python again.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md). Lowering goes through
stablehlo -> XlaComputation with return_tuple=True, so every artifact's
output is a tuple — the rust side unwraps with to_tuple1()/to_tuple2().

Artifacts are shape-specialized. Two shard shapes are emitted:
  (256, 64)   — "small": fast integration tests on the rust side
  (2048, 512) — "canonical": the hot-path shard used by examples/benches
Scalars (eta, mu, lam, ninv) are rank-0 f32 *parameters*, so one artifact
per (entry, shape) serves every hyperparameter setting.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (n_pad, d_pad) shard shapes to specialize. Keep in sync with
# rust/src/runtime/artifact.rs defaults and DESIGN.md §10.
SHAPES = [(256, 64), (2048, 512)]

F32 = jnp.float32


def _spec(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), F32)


def entries_for(n, d):
    """The lowering table: name -> (fn, example arg specs, n_outputs)."""
    mat, vec_n, vec_d, scal = _spec(n, d), _spec(n), _spec(d), _spec()
    return {
        f"ridge_grad_n{n}_d{d}": (
            model.ridge_grad, [mat, vec_n, vec_d, scal, scal], 2),
        f"ridge_local_solve_n{n}_d{d}": (
            model.ridge_local_solve,
            [mat, vec_d, vec_d, scal, scal, scal, scal], 1),
        f"hinge_grad_loss_n{n}_d{d}": (
            model.hinge_grad_loss, [mat, vec_n, vec_d, scal, scal], 2),
        f"hinge_local_solve_n{n}_d{d}": (
            model.hinge_local_solve,
            [mat, vec_n, vec_d, vec_d, scal, scal, scal, scal], 1),
    }


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_json(spec):
    return {"shape": list(spec.shape), "dtype": "f32"}


def build(out_dir: pathlib.Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"format": "hlo-text", "return_tuple": True, "entries": []}
    for n, d in SHAPES:
        for name, (fn, specs, n_out) in entries_for(n, d).items():
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            fname = f"{name}.hlo.txt"
            (out_dir / fname).write_text(text)
            manifest["entries"].append({
                "name": name,
                "file": fname,
                "inputs": [_shape_json(s) for s in specs],
                "n_outputs": n_out,
                "static": {"n": n, "d": d},
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            })
            print(f"  {fname}: {len(text)} chars")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    manifest = build(out_dir)
    print(f"wrote {len(manifest['entries'])} artifacts + manifest.json "
          f"to {out_dir}")


if __name__ == "__main__":
    main()
