"""L2: the per-worker compute graphs of DANE, written in JAX over the L1
Pallas kernels, AOT-lowered once by aot.py and executed from the rust
coordinator via PJRT — Python never runs on the optimization path.

Four entry points (all shard-local; the coordinator owns the averaging):

  ridge_grad(x, y, w, lam, ninv)             -> (grad phi_i(w), phi_i(w))
  ridge_local_solve(x, w_prev, g, eta, mu, lam, ninv) -> w_i  (DANE step)
  hinge_grad_loss(x, y, w, lam, ninv)        -> (grad phi_i(w), phi_i(w))
  hinge_local_solve(x, y, w_prev, g, eta, mu, lam, ninv) -> w_i

Objectives (matching rust/src/loss/ bit-for-bit up to f32 rounding):
  ridge:  phi_i(w) = (1/2n)||Xw - y||^2 + (lam/2)||w||^2
  hinge:  phi_i(w) = (1/n) sum_j l(y_j <x_j,w>) + (lam/2)||w||^2,
          l = smooth hinge (ref.GAMMA).

The DANE local problem (paper eq. 13)
  w_i = argmin_w phi_i(w) - (grad phi_i(w') - eta * g)^T w
                + (mu/2)||w - w'||^2
reduces, for quadratics, to the closed form of paper eq. (16):
  (H_i + mu I)(w_i - w') = -eta * g   with  H_i = (1/n)X^T X + lam I,
solved here by conjugate gradient over the Pallas Gram matvec, so the
Hessian is never materialized. For the smooth hinge the local problem is
solved by damped Newton-CG: the same CG machinery over the weighted Gram
matvec X^T diag(l''(margins)) X, with an Armijo backtracking line search.

Shapes are static at lowering time (canonical padded shard); scalars
(eta, mu, lam, ninv) are passed as rank-0 f32 parameters so one artifact
serves every hyperparameter setting. Padded rows carry x = 0 and y = 0 and
provably contribute nothing to any output; ninv must be 1/n_real.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import gram_matvec, hinge_grad
from .kernels.gram_matvec import resid_matvec_ss
from .kernels.ref import GAMMA

# Static solve budgets, baked into the lowered HLO. CG on a d-dimensional
# SPD system terminates in <= d steps exactly; the tolerance exit fires far
# earlier on the well-clustered spectra these problems have.
CG_MAX_ITERS = 200
CG_TOL = 1e-7
NEWTON_MAX_STEPS = 30
NEWTON_GRAD_TOL = 1e-9
ARMIJO_C = 1e-4
ARMIJO_MAX_HALVINGS = 30


def _cg(matvec, b, tol=CG_TOL, max_iters=CG_MAX_ITERS):
    """Conjugate gradient for SPD ``matvec(x) = b``, from x0 = 0.

    Tolerance is on ||r|| relative to ||b||; lax.while_loop keeps the
    lowered HLO compact (a single loop region, not an unrolled chain).
    """
    bnorm2 = b @ b
    stop2 = (tol * tol) * bnorm2

    def cond(state):
        k, _x, _r, _p, rs = state
        return (k < max_iters) & (rs > stop2)

    def body(state):
        k, x, r, p, rs = state
        ap = matvec(p)
        alpha = rs / (p @ ap)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = r @ r
        p = r + (rs_new / rs) * p
        return (k + 1, x, r, p, rs_new)

    state = (jnp.asarray(0, jnp.int32), jnp.zeros_like(b), b, b, bnorm2)
    _, x, _, _, _ = jax.lax.while_loop(cond, body, state)
    return x


# --------------------------------------------------------------------------
# Ridge (quadratic) path — paper fig. 2
# --------------------------------------------------------------------------

def ridge_grad(x, y, w, lam, ninv):
    """(grad phi_i(w), phi_i(w)) in ONE Pallas pass over X:
    grad = (1/n) X^T (X w - y) + lam w,
    loss = (1/2n) ||X w - y||^2 + (lam/2)||w||^2."""
    ones = jnp.ones((x.shape[0],), x.dtype)
    g_raw, ss = resid_matvec_ss(x, ones, w, y)
    grad = ninv * g_raw + lam * w
    loss = 0.5 * ninv * ss[0] + 0.5 * lam * (w @ w)
    return grad, loss


def ridge_local_solve(x, w_prev, g, eta, mu, lam, ninv):
    """DANE local step for the quadratic objective (paper eq. 16).

    Solves (H_i + mu I) delta = g by CG over the Pallas Gram matvec and
    returns w_i = w_prev - eta * delta. ``g`` is the *global* averaged
    gradient at w_prev (the only state the coordinator must broadcast).
    """
    ones = jnp.ones((x.shape[0],), x.dtype)

    def matvec(v):
        return ninv * gram_matvec(x, ones, v) + (lam + mu) * v

    delta = _cg(matvec, g)
    return w_prev - eta * delta


# --------------------------------------------------------------------------
# Smooth-hinge path — paper figs. 3, 4
# --------------------------------------------------------------------------

def hinge_grad_loss(x, y, w, lam, ninv):
    """(grad phi_i(w), phi_i(w)) for the regularized smooth hinge, fused."""
    g_sum, loss_sum = hinge_grad(x, y, w)
    grad = ninv * g_sum + lam * w
    loss = ninv * loss_sum[0] + 0.5 * lam * (w @ w)
    return grad, loss


def hinge_local_solve(x, y, w_prev, g, eta, mu, lam, ninv):
    """DANE local step for the smooth hinge, by damped Newton-CG.

    Local objective (paper eq. 13):
      h(w) = phi_i(w) - c^T w + (mu/2)||w - w_prev||^2,
      c    = grad phi_i(w_prev) - eta * g.
    Each Newton step solves  (H_i(w) + mu I) delta = grad h(w)  with CG over
    the weighted Pallas Gram matvec (D = l''(margins); padded rows have
    y = 0 so y^2 masks them), then backtracks on h until Armijo holds.
    """
    gp, _ = hinge_grad_loss(x, y, w_prev, lam, ninv)
    c = gp - eta * g

    def h_grad_val(w):
        gphi, lphi = hinge_grad_loss(x, y, w, lam, ninv)
        diff = w - w_prev
        gh = gphi - c + mu * diff
        hv = lphi - c @ w + 0.5 * mu * (diff @ diff)
        return gh, hv

    def newton_cond(state):
        k, _w, gh, _hv = state
        return (k < NEWTON_MAX_STEPS) & (gh @ gh > NEWTON_GRAD_TOL**2)

    def newton_body(state):
        k, w, gh, hv = state
        margins = y * (x @ w)
        # l''(m) * y^2: curvature weight, zero on padded rows (y = 0).
        dvec = jnp.where(
            (margins < 1.0) & (margins > 1.0 - GAMMA), 1.0 / GAMMA, 0.0
        ) * y * y

        def hvp(v):
            return ninv * gram_matvec(x, dvec, v) + (lam + mu) * v

        delta = _cg(hvp, gh)
        slope = gh @ delta  # > 0: delta is a descent direction for -delta

        def bt_cond(bt):
            j, _wn, hn, s = bt
            armijo = hn <= hv - ARMIJO_C * s * slope
            return (j < ARMIJO_MAX_HALVINGS) & ~armijo

        def bt_body(bt):
            j, _wn, _hn, s = bt
            s = s * 0.5
            wn = w - s * delta
            _, hn = h_grad_val(wn)
            return (j + 1, wn, hn, s)

        w1 = w - delta
        _, h1 = h_grad_val(w1)
        _, wn, _hn, _ = jax.lax.while_loop(
            bt_cond, bt_body, (jnp.asarray(0, jnp.int32), w1, h1, jnp.asarray(1.0, x.dtype))
        )
        ghn, hvn = h_grad_val(wn)
        return (k + 1, wn, ghn, hvn)

    gh0, hv0 = h_grad_val(w_prev)
    state = (jnp.asarray(0, jnp.int32), w_prev, gh0, hv0)
    _, w_out, _, _ = jax.lax.while_loop(newton_cond, newton_body, state)
    return w_out


# --------------------------------------------------------------------------
# Jitted conveniences for tests (AOT lowering happens in aot.py)
# --------------------------------------------------------------------------

ridge_grad_jit = jax.jit(ridge_grad)
ridge_local_solve_jit = jax.jit(ridge_local_solve)
hinge_grad_loss_jit = jax.jit(hinge_grad_loss)
hinge_local_solve_jit = jax.jit(hinge_local_solve)
