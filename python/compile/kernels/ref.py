"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal for the L1 layer: every Pallas kernel
in this package must agree with its oracle here (pytest + hypothesis sweep
shapes/dtypes and assert_allclose). The oracles are deliberately written in
the most obvious jnp form — no tiling, no fusion — so a reviewer can check
them against the paper's formulas by eye.

Conventions (shared with model.py and the rust side):
  x : (n, d)  feature matrix of one worker's shard (zero-padded rows allowed)
  y : (n,)    ridge targets, or +/-1 labels (0 on padded rows)
  v : (d,)    direction vector (CG iterate)
  w : (d,)    parameter vector
  dvec : (n,) per-row curvature weights (0 on padded rows)

Smooth hinge (Shalev-Shwartz & Zhang 2013), smoothing parameter gamma:
  l(a) = 0                 if a >= 1
       = 1 - a - gamma/2   if a <= 1 - gamma
       = (1-a)^2/(2 gamma) otherwise
Its derivative and second derivative follow piecewise. The paper's
figures 3-4 use this loss with L2 regularization.
"""

import jax.numpy as jnp

GAMMA = 1.0  # paper-default smoothing for the smooth hinge


def gram_matvec_ref(x, dvec, v):
    """Weighted Gram-matrix/vector product: x^T (dvec * (x v)).

    With dvec == 1 this is the plain Gram matvec x^T x v — the Hessian-vector
    product of the (unregularized, unscaled) ridge objective, and the
    workhorse of every CG-based local solve ("no Hessians are explicitly
    computed!"). With dvec = l''(margins) it is the smooth-hinge HVP.
    """
    t = x @ v
    return x.T @ (dvec * t)


def smooth_hinge(a, gamma=GAMMA):
    """Element-wise smooth hinge loss l(a)."""
    return jnp.where(
        a >= 1.0,
        0.0,
        jnp.where(a <= 1.0 - gamma, 1.0 - a - gamma / 2.0, (1.0 - a) ** 2 / (2.0 * gamma)),
    )


def smooth_hinge_d(a, gamma=GAMMA):
    """Element-wise derivative l'(a)."""
    return jnp.where(
        a >= 1.0,
        0.0,
        jnp.where(a <= 1.0 - gamma, -1.0, -(1.0 - a) / gamma),
    )


def smooth_hinge_dd(a, gamma=GAMMA):
    """Element-wise second derivative l''(a) (defined a.e.)."""
    return jnp.where((a < 1.0) & (a > 1.0 - gamma), 1.0 / gamma, 0.0)


def hinge_grad_ref(x, y, w, gamma=GAMMA):
    """Unscaled smooth-hinge pieces of one shard.

    Returns (g_sum, loss_sum) where
      g_sum    = sum_j l'(y_j <x_j, w>) * y_j * x_j          (shape (d,))
      loss_sum = sum_j l(y_j <x_j, w>)                       (scalar)
    Scaling by 1/n and adding the lam*w ridge term happen in model.py /
    rust — keeping the kernel pure makes padding-row handling (y=0 rows
    must contribute nothing: l'(0)*0 = 0 for the gradient, and the loss
    term is masked by y != 0) explicit and testable.
    """
    margins = y * (x @ w)
    valid = (y != 0.0).astype(x.dtype)
    dcoef = smooth_hinge_d(margins, gamma) * y  # y==0 rows vanish here
    g_sum = x.T @ dcoef
    loss_sum = jnp.sum(smooth_hinge(margins, gamma) * valid)
    return g_sum, loss_sum


def resid_matvec_ref(x, dvec, v, r):
    """Weighted residual matvec: x^T (dvec * (x v - r))."""
    return x.T @ (dvec * (x @ v - r))


def ridge_resid_grad_ref(x, y, w):
    """Unscaled ridge residual gradient of one shard: x^T (x w - y)."""
    return x.T @ (x @ w - y)
