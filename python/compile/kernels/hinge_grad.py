"""Pallas kernel: fused smooth-hinge gradient + loss over one shard.

Computes, in a single streamed pass over the shard matrix X:

    margins  = y * (X @ w)                (bm,)  MXU + VPU
    dcoef    = l'(margins) * y            (bm,)  VPU piecewise
    g_sum   += X^T @ dcoef                (d,)   MXU accumulate
    loss    += sum(l(margins) * [y != 0]) ()     VPU reduce

The naive jnp composition (ref.hinge_grad_ref) reads X twice (once for the
margins, once for the X^T reduction) and materializes the (n,) temporaries
in HBM; the fused kernel keeps everything block-local in VMEM. Padding rows
carry y = 0 and therefore contribute exactly zero to both outputs (masked
loss, and dcoef = l'(0) * 0 = 0).

Smooth hinge (Shalev-Shwartz & Zhang 2013) with smoothing gamma:
    l(a)  = 0                  a >= 1
          = 1 - a - gamma/2    a <= 1 - gamma
          = (1-a)^2/(2 gamma)  otherwise
    l'(a) = 0 / -1 / -(1-a)/gamma on the same pieces.

interpret=True is mandatory on this image (CPU PJRT cannot execute Mosaic
custom-calls); the sequential grid makes the accumulators safe.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gram_matvec import effective_block_rows
from .ref import GAMMA

DEFAULT_BLOCK_ROWS = 256


def _hinge_kernel(gamma, x_ref, y_ref, w_ref, g_ref, l_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        l_ref[...] = jnp.zeros_like(l_ref)

    x_blk = x_ref[...]                       # (bm, d)
    y_blk = y_ref[...]                       # (bm,)
    margins = y_blk * (x_blk @ w_ref[...])   # (bm,)

    one = 1.0
    dcoef = jnp.where(
        margins >= one,
        0.0,
        jnp.where(margins <= one - gamma, -1.0, -(one - margins) / gamma),
    ) * y_blk                                # y=0 padding rows vanish
    losses = jnp.where(
        margins >= one,
        0.0,
        jnp.where(
            margins <= one - gamma,
            one - margins - gamma / 2.0,
            (one - margins) ** 2 / (2.0 * gamma),
        ),
    ) * (y_blk != 0.0).astype(margins.dtype)

    g_ref[...] += x_blk.T @ dcoef
    l_ref[...] += jnp.sum(losses)[None]


@functools.partial(
    jax.jit, static_argnames=("gamma", "block_rows", "interpret")
)
def hinge_grad(x, y, w, *, gamma=GAMMA, block_rows=DEFAULT_BLOCK_ROWS,
               interpret=True):
    """Fused shard-local smooth-hinge pieces.

    Args:
      x: (n, d) shard matrix, n divisible by ``block_rows``.
      y: (n,) labels in {-1, +1}, exactly 0 on zero-padded rows.
      w: (d,) parameter vector.
      gamma: smooth-hinge smoothing parameter (paper default 1.0).

    Returns:
      (g_sum, loss_sum): unscaled sums over the shard —
      g_sum = sum_j l'(y_j<x_j,w>) y_j x_j  (d,) and
      loss_sum = sum_j l(y_j<x_j,w>)        (1,).
      The caller applies 1/n scaling and the lam*w ridge term.
    """
    n, d = x.shape
    block_rows = effective_block_rows(n, block_rows)
    grid = (n // block_rows,)
    kernel = functools.partial(_hinge_kernel, float(gamma))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=interpret,
    )(x, y, w)
