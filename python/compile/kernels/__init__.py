"""L1: Pallas kernels for the paper's compute hot spot.

Every kernel here has a pure-jnp oracle in ref.py; pytest + hypothesis
assert agreement across shapes and dtypes. Kernels run interpret=True
(CPU PJRT cannot execute Mosaic custom-calls) — see DESIGN.md
§Hardware-Adaptation for the TPU mapping they encode.
"""

from .gram_matvec import gram_matvec, resid_matvec
from .hinge_grad import hinge_grad

__all__ = ["gram_matvec", "resid_matvec", "hinge_grad"]
