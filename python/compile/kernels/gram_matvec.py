"""Pallas kernel: tiled weighted Gram-matrix/vector product x^T (dvec * (x v)).

This is the hot spot of every DANE local solve: conjugate gradient on the
local system (H_i + mu I) delta = g performs one Gram matvec per iteration,
and the Gram matvec is the only operation that touches the shard matrix X.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks row-blocks
of X; each step stages a (block_rows, d) tile of X into VMEM, runs two MXU
matmuls — t = X_blk @ v, then acc += X_blk^T @ (dvec_blk * t) — and leaves
the (d,) accumulator resident in VMEM across the whole grid (its index_map
is constant, so Pallas revisits the same output block every step). X is
streamed through HBM exactly once per call; the naive jnp form
``x.T @ (dvec * (x @ v))`` takes two HBM passes over X unless XLA happens
to fuse them.

interpret=True is mandatory on this image: the CPU PJRT plugin cannot run
Mosaic custom-calls. The grid is executed sequentially in interpret mode
(and on a single TPU core), so the accumulate-in-place pattern is safe.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def effective_block_rows(n, requested):
    """Largest usable row-block: the requested size when it divides n,
    n itself for small inputs; anything else is a caller error (shards
    are padded to artifact shapes that are multiples of the default)."""
    if n <= requested:
        return n
    if n % requested == 0:
        return requested
    raise ValueError(f"n={n} not divisible by block_rows={requested}")


def _resid_matvec_kernel(x_ref, d_ref, v_ref, r_ref, o_ref, ss_ref):
    """One grid step: o += x_blk^T (dvec_blk * t), ss += sum(dvec * t^2),
    with t = x_blk @ v - r_blk."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        ss_ref[...] = jnp.zeros_like(ss_ref)

    x_blk = x_ref[...]              # (bm, d) tile, staged in VMEM
    t = x_blk @ v_ref[...] - r_ref[...]  # (bm,) first MXU pass + residual
    tw = t * d_ref[...]             # (bm,)   VPU elementwise weight
    o_ref[...] += x_blk.T @ tw      # (d,)    second MXU pass, accumulate
    ss_ref[...] += jnp.sum(tw * t)[None]  # weighted residual sum-of-squares


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def resid_matvec_ss(x, dvec, v, r, *, block_rows=DEFAULT_BLOCK_ROWS,
                    interpret=True):
    """One streamed pass over x computing BOTH
    ``x.T @ (dvec * (x @ v - r))`` and the weighted residual sum of
    squares ``sum(dvec * (x @ v - r)^2)``.

    The general form serves every hot path:
      * r = 0, dvec = 1      -> plain Gram matvec x^T x v  (CG iterations)
      * r = y, dvec = 1      -> ridge residual gradient + 2n * loss
      * r = 0, dvec = l''(m) -> smooth-hinge Hessian-vector product

    Args:
      x: (n, d) shard matrix; n must be divisible by ``block_rows``
         (callers zero-pad — zero rows contribute nothing).
      dvec: (n,) per-row weights (0 on padding).
      v: (d,) direction vector.
      r: (n,) per-row offsets subtracted from x @ v.
      block_rows: rows of x staged per grid step. VMEM footprint is
         ~ block_rows*d*4 bytes for the tile + 2*d*4 for v and the
         accumulator; 256x512 f32 = 512 KiB, far under the 16 MiB VMEM
         budget, leaving room for double-buffering the streamed tile.
      interpret: must stay True for CPU PJRT (Mosaic custom-calls do not
         run there); False only as a compile-only TPU target.

    Returns: ((d,) vector, (1,) sum of squares).
    """
    n, d = x.shape
    block_rows = effective_block_rows(n, block_rows)
    grid = (n // block_rows,)
    return pl.pallas_call(
        _resid_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),  # stream X tiles
            pl.BlockSpec((block_rows,), lambda i: (i,)),      # stream dvec
            pl.BlockSpec((d,), lambda i: (0,)),               # v resident
            pl.BlockSpec((block_rows,), lambda i: (i,)),      # stream r
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),               # acc resident
            pl.BlockSpec((1,), lambda i: (0,)),               # ss resident
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=interpret,
    )(x, dvec, v, r)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def resid_matvec(x, dvec, v, r, *, block_rows=DEFAULT_BLOCK_ROWS,
                 interpret=True):
    """``x.T @ (dvec * (x @ v - r))`` (sum-of-squares output dropped)."""
    out, _ss = resid_matvec_ss(x, dvec, v, r, block_rows=block_rows,
                               interpret=interpret)
    return out


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gram_matvec(x, dvec, v, *, block_rows=DEFAULT_BLOCK_ROWS, interpret=True):
    """``x.T @ (dvec * (x @ v))`` — resid_matvec with a zero offset."""
    n, _ = x.shape
    return resid_matvec(x, dvec, v, jnp.zeros((n,), x.dtype),
                        block_rows=block_rows, interpret=interpret)
